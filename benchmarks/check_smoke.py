"""CI gate over the bench-smoke artifacts.

Reads the ``BENCH_*.json`` files emitted by ``benchmarks.run`` and fails
(exit 1) when a regression lands:

* explorer: batched dispatch counts must stay well under the serial
  path's (the population batching exists to collapse them), and the
  batched/serial Pareto fronts must stay identical;
* explorer-dynamic: a dynamic-objective exploration must issue at most
  ``MAX_DYNAMIC_EXTRA_DISPATCHES`` more compiled dispatches than the
  static objective at identical budget (the bit-census accumulators ride
  the existing vmapped dispatch), the device-folded dynamic energies
  must match the host-side ``dynamic_fpu_energy`` reference to
  ``DYNAMIC_HOST_DEVICE_RTOL``, and dynamic energy must never exceed
  static for identical genomes;
* serve: the continuous engine must take <= 1/1.5 the compiled decode
  steps of the wave engine on the skewed workload, with identical greedy
  completions. Step time is constant at fixed batch shape, so the steps
  ratio is the deterministic form of the tokens/sec speedup.
* serve-prefill: chunked prefill must cut mean time-to-first-token by
  >= ``MIN_TTFT_SPEEDUP`` over streaming prefill on the skewed workload
  (expected ~an order of magnitude: 32-token chunks collapse ~96
  per-token dispatches into 3), with greedy completions identical to the
  wave reference; the chunked/streaming prefill *step* counts must also
  differ by >= the same factor (the deterministic form of the TTFT win).
* serve-paged: the paged pool + packed prefill must beat the rectangle
  path by >= ``MIN_PAGED_SPEEDUP`` tokens/sec at fixed KV memory, admit
  >= ``MIN_PAGED_CONCURRENCY`` x the contiguous slot cap concurrently,
  keep resident pages at or below the pool (the memory-ceiling claim),
  and reproduce the rectangle engine's greedy completions exactly.
* serve-spec: the NEAT reduced-precision drafter must beat the
  non-speculative paged engine by >= ``MIN_SPEC_SPEEDUP`` tokens/sec at
  drafter_bits=10 with acceptance >= ``MIN_SPEC_ACCEPTANCE``, greedy
  completions byte-identical to the non-speculative engine at every
  bits level AND on tiny models of all five families, and a p99 TTFT
  tail within ``MAX_SPEC_P99_TTFT_RATIO`` x the non-speculative
  engine's.
* serve-policy: a phase/layer-heterogeneous policy from
  ``explore(objectives="serving")`` must beat the best whole-program
  uniform drafter (lower *measured* fused-census pJ/token, both
  holding the ``MIN_POLICY_ACCEPTANCE`` SLA floor — the per-site
  placement claim, end to end in the engine), reduce measured
  pJ/token by >=
  ``MIN_POLICY_ENERGY_REDUCTION`` over the PR-6 ``drafter_bits=10``
  baseline at acceptance >= ``MIN_POLICY_ACCEPTANCE``, explore a
  non-degenerate measured front (>= 2 distinct positive token-stream
  census energies), keep every arm's greedy completions byte-identical
  to non-policy serving (including the tiered engine's exact tier),
  and hold p99 TTFT within ``MAX_POLICY_P99_TTFT_RATIO`` x the
  baseline's.
* serve-async: fused decode megasteps must beat the sync-every-token
  loop by >= ``MIN_ASYNC_SPEEDUP`` tokens/sec at ``sync_every=32`` on
  the decode-dominated workload, with host syncs bounded by
  steps/sync_every plus scheduling events, byte-identical greedy
  completions (all five families), and the measured fused-census
  pJ/token equal to the single-step path within
  ``ASYNC_CENSUS_RTOL``.
* serve-burst: bursty-traffic hardening — at a pool too small for the
  workload's worst case, lazy page growth + preemption must hold >=
  ``MIN_BURST_CONCURRENCY`` x the concurrent requests of worst-case
  reservation with byte-identical greedy completions (both arms and an
  ample-pool reference); poison requests (expired ``deadline_s=0`` TTFT
  SLA, a budget needing more pages than the whole pool) must retire as
  ``shed_deadline`` / ``shed_capacity`` statuses — never a raise —
  while the rest of the batch completes byte-identically; every engine
  runs ``debug_invariants=True`` so a page/swap-ledger accounting
  violation fails the bench itself. Against the committed baseline the
  open-loop Poisson arm's p99 TTFT may grow at most
  ``BURST_TTFT_BASELINE_RATIO`` x (wall clock — wide tolerance),
  goodput fraction must keep ``MIN_BURST_GOODPUT_OF_BASE`` of the
  recorded value and shed rate may exceed it by at most
  ``BURST_SHED_RATE_EPS`` (both status-determined — tight).
* kernels-paged: the multi-page paged-attention blocking must fill the
  MXU tile at small page sizes (KV grid trips at ``page_size=8 x
  pages_per_block=16`` == the ``page_size=128`` reference; paged serve
  steps at ``page_size=8`` no worse than the wide-page layout, with
  identical completions), the fused kernel-epilogue census must match
  the host ``bit_census_ref`` within ``DYNAMIC_HOST_DEVICE_RTOL``, and
  a census-collecting serve may issue at most
  ``MAX_DYNAMIC_EXTRA_DISPATCHES`` extra compiled steps over the same
  run with the census off while folding a nonzero measured census.

On top of the absolute gates, every artifact with a **committed
baseline** (``benchmarks/baselines/BENCH_*.json``) is compared against
it with a tolerance: deterministic count fields (steps, dispatches) may
grow at most ``BASELINE_COUNT_TOL``; relative speedup fields may shrink
to at most ``BASELINE_RATIO_TOL`` of the recorded value. Raw wall-clock
fields (us, tokens/sec) are never baseline-gated — CI runners differ —
only ratios of two same-run measurements and exact counts are. Refresh
the baselines in the same PR as an intentional perf change:

  PYTHONPATH=src python -m benchmarks.run \
      --only explorer,serve,kernels-paged --json-dir benchmarks/baselines

  python -m benchmarks.check_smoke [--json-dir .]
      [--baseline-dir benchmarks/baselines]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

MIN_SERVE_SPEEDUP = 1.5
MIN_TTFT_SPEEDUP = 2.0             # chunked vs streaming prefill
MIN_PAGED_SPEEDUP = 1.3            # paged+packed vs rectangle, fixed KV
MIN_PAGED_CONCURRENCY = 2.0        # peak active vs contiguous slot cap
MIN_SPEC_SPEEDUP = 1.5             # speculative vs paged non-spec, bits=10
MIN_SPEC_ACCEPTANCE = 0.6          # draft acceptance at bits=10
MAX_SPEC_P99_TTFT_RATIO = 4.0      # spec p99 TTFT tail vs non-spec (the
#                                    drafter adds per-window latency; the
#                                    tail must stay bounded, not shrink)
MIN_POLICY_ENERGY_REDUCTION = 1.01  # explored policy pJ/token vs the
#                                     uniform drafter_bits=10 baseline
#                                     (deterministic abstract census)
MIN_POLICY_ACCEPTANCE = 0.9        # acceptance under the explored policy
MAX_POLICY_P99_TTFT_RATIO = 2.5    # policy p99 TTFT vs the uniform
#                                    drafter baseline (same engine shape;
#                                    observed ~1.3x, wall-clock headroom)
MIN_ASYNC_SPEEDUP = 1.3            # fused megasteps (sync_every=32) vs
#                                    the sync-every-token loop, tokens/s
ASYNC_CENSUS_RTOL = 1e-6           # measured pJ/token, megastep vs
#                                    single-step (exact by construction)
MIN_BURST_CONCURRENCY = 1.5        # lazy+preempt peak concurrent reqs vs
#                                    worst-case reservation, fixed pool
MAX_BURST_P99_TTFT_MS = 60_000.0   # open-loop p99 TTFT sanity ceiling
BURST_TTFT_BASELINE_RATIO = 3.0    # p99 TTFT vs committed baseline
#                                    (wall clock on shared CI runners)
BURST_TTFT_ABS_FLOOR_MS = 250.0    # ignore ratio blowups below this —
#                                    a 5 ms baseline tripling is noise
MIN_BURST_GOODPUT_OF_BASE = 0.9    # goodput fraction vs baseline
BURST_SHED_RATE_EPS = 0.05         # shed rate may exceed baseline by
MAX_DISPATCH_RATIO = 0.25          # batched <= serial / 4
MAX_DYNAMIC_EXTRA_DISPATCHES = 2   # dynamic objective <= static + 2
DYNAMIC_HOST_DEVICE_RTOL = 1e-6

# baseline gating: counts may regress by 10%, ratios keep 75% of the
# recorded win (CI noise headroom; the absolute gates still apply)
BASELINE_COUNT_TOL = 1.10
BASELINE_RATIO_TOL = 0.75
#: derived fields gated against the committed baseline, by direction:
#: "le" = current <= baseline * BASELINE_COUNT_TOL (deterministic
#: counts), "ge" = current >= baseline * BASELINE_RATIO_TOL (relative
#: speedups). Everything else (wall clock, memory high-water marks that
#: only have absolute gates) is reported, not baseline-gated.
BASELINE_GATES = {
    "steps": "le",
    "prefill_steps": "le",
    "host_syncs": "le",
    "batched": "le",
    "dynamic": "le",
    "speedup": "ge",
    "ttft_speedup": "ge",
    "concurrency": "ge",
    "acceptance": "ge",
    "energy_reduction": "ge",
    "pj_per_tok": "le",
}


def _rows(path: str) -> dict:
    with open(path) as f:
        return {name: derived for name, _, derived in json.load(f)["rows"]}


def _field(derived: str, key: str) -> str:
    for part in derived.split(";"):
        if part.startswith(key + "="):
            return part.split("=", 1)[1]
    raise KeyError(f"{key!r} not in {derived!r}")


def check_explorer(path: str) -> list:
    rows = _rows(path)
    errs = []
    disp = rows["explorer_dispatches"]
    batched = int(_field(disp, "batched"))
    serial = int(_field(disp, "serial"))
    if batched > serial * MAX_DISPATCH_RATIO:
        errs.append(f"explorer dispatch regression: batched={batched} "
                    f"vs serial={serial}")
    if not rows["explorer_front_identical"].startswith("True"):
        errs.append("explorer Pareto parity regression: batched front != "
                    f"serial front ({rows['explorer_front_identical']})")
    return errs


def check_explorer_dynamic(path: str) -> list:
    rows = _rows(path)
    errs = []
    disp = rows["explorer_dynamic_dispatches"]
    dyn = int(_field(disp, "dynamic"))
    stat = int(_field(disp, "static"))
    if dyn > stat + MAX_DYNAMIC_EXTRA_DISPATCHES:
        errs.append(f"dynamic-objective dispatch regression: dynamic={dyn} "
                    f"vs static={stat} (allowed +"
                    f"{MAX_DYNAMIC_EXTRA_DISPATCHES})")
    rel = float(_field(rows["explorer_dynamic_host_device"],
                       "max_rel_diff"))
    if not rel <= DYNAMIC_HOST_DEVICE_RTOL:
        errs.append(f"dynamic energy host/device divergence: max rel diff "
                    f"{rel:.3e} > {DYNAMIC_HOST_DEVICE_RTOL}")
    if _field(rows["explorer_dynamic_sanity"], "dyn_le_static") != "True":
        errs.append("dynamic energy exceeded static for an identical "
                    "genome — the census upper bound is broken")
    return errs


def check_serve(path: str) -> list:
    rows = _rows(path)
    errs = []
    cont_steps = int(_field(rows["serve_continuous"], "steps"))
    wave_steps = int(_field(rows["serve_wave"], "steps"))
    step_speedup = wave_steps / max(cont_steps, 1)
    if step_speedup < MIN_SERVE_SPEEDUP:
        errs.append(f"serve speedup regression: wave/continuous step "
                    f"ratio {step_speedup:.2f}x < {MIN_SERVE_SPEEDUP}x "
                    f"(wave={wave_steps}, continuous={cont_steps})")
    if _field(rows["serve_speedup"], "parity") != "True":
        errs.append("serve parity regression: continuous != wave "
                    "completions under greedy decoding")
    return errs


def check_serve_prefill(path: str) -> list:
    rows = _rows(path)
    errs = []
    ttft = float(_field(rows["serve_prefill_speedup"], "ttft_speedup")
                 .rstrip("x"))
    if ttft < MIN_TTFT_SPEEDUP:
        errs.append(f"chunked-prefill TTFT regression: {ttft:.2f}x < "
                    f"{MIN_TTFT_SPEEDUP}x over streaming prefill")
    ch_steps = int(_field(rows["serve_prefill_chunked"], "prefill_steps"))
    st_steps = int(_field(rows["serve_prefill_streaming"],
                          "prefill_steps"))
    step_ratio = st_steps / max(ch_steps, 1)
    if step_ratio < MIN_TTFT_SPEEDUP:
        errs.append(f"chunked-prefill step regression: streaming/chunked "
                    f"prefill-step ratio {step_ratio:.2f}x < "
                    f"{MIN_TTFT_SPEEDUP}x (streaming={st_steps}, "
                    f"chunked={ch_steps})")
    if _field(rows["serve_prefill_speedup"], "parity") != "True":
        errs.append("chunked-prefill parity regression: chunked != wave "
                    "greedy completions")
    return errs


def check_serve_paged(path: str) -> list:
    rows = _rows(path)
    errs = []
    speed = float(_field(rows["serve_paged_speedup"], "speedup")
                  .rstrip("x"))
    if speed < MIN_PAGED_SPEEDUP:
        errs.append(f"paged-serve speedup regression: {speed:.2f}x < "
                    f"{MIN_PAGED_SPEEDUP}x over the rectangle path at "
                    "fixed KV memory")
    conc = float(_field(rows["serve_paged_speedup"], "concurrency")
                 .rstrip("x"))
    if conc < MIN_PAGED_CONCURRENCY:
        errs.append(f"paged-serve concurrency regression: {conc:.2f}x < "
                    f"{MIN_PAGED_CONCURRENCY}x the contiguous slot cap "
                    "at fixed KV memory")
    peak = int(_field(rows["serve_paged"], "peak_pages"))
    pool = int(_field(rows["serve_paged"], "pool"))
    if peak > pool:
        errs.append(f"paged-serve memory ceiling broken: "
                    f"{peak} resident pages > pool of {pool}")
    if _field(rows["serve_paged_speedup"], "parity") != "True":
        errs.append("paged-serve parity regression: paged != rectangle "
                    "greedy completions")
    return errs


def check_serve_spec(path: str) -> list:
    rows = _rows(path)
    errs = []
    sp = rows["serve_spec_speedup"]
    speed = float(_field(sp, "speedup").rstrip("x"))
    if speed < MIN_SPEC_SPEEDUP:
        errs.append(f"speculative-serve speedup regression: {speed:.2f}x "
                    f"< {MIN_SPEC_SPEEDUP}x over the non-speculative "
                    "paged engine at bits=10")
    acc = float(_field(sp, "acceptance"))
    if acc < MIN_SPEC_ACCEPTANCE:
        errs.append(f"draft acceptance regression: {acc:.3f} < "
                    f"{MIN_SPEC_ACCEPTANCE} at drafter_bits=10")
    if _field(sp, "parity") != "True":
        errs.append("speculative-serve parity regression: spec greedy "
                    "completions != non-speculative (any bits level)")
    if _field(sp, "families_parity") != "True":
        errs.append("speculative-serve family-parity regression: a "
                    "family's spec completions diverged from its "
                    "non-speculative engine")
    ratio = float(_field(sp, "ttft_p99_ratio").rstrip("x"))
    if ratio > MAX_SPEC_P99_TTFT_RATIO:
        errs.append(f"speculative-serve p99 TTFT tail regression: "
                    f"{ratio:.2f}x > {MAX_SPEC_P99_TTFT_RATIO}x the "
                    "non-speculative engine's tail")
    return errs


def check_serve_policy(path: str) -> list:
    rows = _rows(path)
    errs = []
    gate = rows["serve_policy_gate"]
    if _field(gate, "hetero_beats_uniform") != "True":
        errs.append("policy-serve placement regression: no heterogeneous "
                    "policy beat the best uniform drafter (lower measured "
                    "pJ/token at the acceptance SLA floor)")
    red = float(_field(gate, "energy_reduction").rstrip("x"))
    if red < MIN_POLICY_ENERGY_REDUCTION:
        errs.append(f"policy-serve energy regression: {red:.3f}x < "
                    f"{MIN_POLICY_ENERGY_REDUCTION}x measured pJ/token "
                    "reduction over the uniform drafter_bits=10 baseline")
    if _field(gate, "measured_front") != "True":
        errs.append("policy-serve measured-front regression: the "
                    "explored points' fused-census energies are "
                    "degenerate (fewer than 2 distinct positive values) "
                    "— the serving energy axis stopped measuring")
    acc = float(_field(gate, "acceptance"))
    if acc < MIN_POLICY_ACCEPTANCE:
        errs.append(f"policy-serve acceptance regression: {acc:.3f} < "
                    f"{MIN_POLICY_ACCEPTANCE} under the explored policy")
    if _field(gate, "parity") != "True":
        errs.append("policy-serve parity regression: an arm's greedy "
                    "completions diverged from non-policy serving (or "
                    "the turbo tier stopped being cheaper than exact)")
    if _field(rows["serve_policy_tiered"], "exact_parity") != "True":
        errs.append("policy-serve tier regression: the exact tier's "
                    "completions != non-policy serving")
    ratio = float(_field(gate, "ttft_p99_ratio").rstrip("x"))
    if ratio > MAX_POLICY_P99_TTFT_RATIO:
        errs.append(f"policy-serve p99 TTFT tail regression: "
                    f"{ratio:.2f}x > {MAX_POLICY_P99_TTFT_RATIO}x the "
                    "uniform-drafter baseline's tail")
    return errs


def check_serve_async(path: str) -> list:
    rows = _rows(path)
    errs = []
    sp = rows["serve_async_speedup"]
    speed = float(_field(sp, "speedup").rstrip("x"))
    if speed < MIN_ASYNC_SPEEDUP:
        errs.append(f"async-serve speedup regression: {speed:.2f}x < "
                    f"{MIN_ASYNC_SPEEDUP}x tokens/sec at sync_every=32 "
                    "over the sync-every-token loop")
    if _field(sp, "parity") != "True":
        errs.append("async-serve parity regression: megastep greedy "
                    "completions != single-step loop")
    if _field(sp, "families_parity") != "True":
        errs.append("async-serve family-parity regression: a family's "
                    "fused-megastep completions diverged from its "
                    "single-step engine")
    if _field(sp, "sync_bound") != "True":
        errs.append("async-serve host-sync regression: host_syncs "
                    "exceeded steps/sync_every + scheduling events "
                    f"(host_syncs_32={_field(sp, 'host_syncs_32')})")
    census_rel = float(_field(sp, "census_rel"))
    if not census_rel <= ASYNC_CENSUS_RTOL:
        errs.append(f"async-serve census divergence: measured pJ/token "
                    f"rel diff {census_rel:.3e} > {ASYNC_CENSUS_RTOL} "
                    "vs the single-step path")
    return errs


def check_serve_burst(path: str) -> list:
    rows = _rows(path)
    errs = []
    res = rows["serve_burst_reservation"]
    conc = float(_field(res, "concurrency").rstrip("x"))
    if conc < MIN_BURST_CONCURRENCY:
        errs.append(f"burst-serve concurrency regression: lazy+preempt "
                    f"held {conc:.2f}x < {MIN_BURST_CONCURRENCY}x the "
                    "worst-case reservation's concurrent requests at a "
                    "fixed pool")
    if _field(res, "parity") != "True":
        errs.append("burst-serve parity regression: lazy+preempt (or "
                    "worst-case) completions diverged from the "
                    "ample-pool reference under forced preemption")
    shed = rows["serve_burst_shed"]
    if _field(shed, "statuses_ok") != "True":
        errs.append("burst-serve structured-failure regression: poison "
                    "requests did not retire as shed_deadline/"
                    "shed_capacity with the rest of the batch "
                    "byte-identical")
    if int(_field(shed, "shed_deadline")) < 1 \
            or int(_field(shed, "shed_capacity")) < 1:
        errs.append("burst-serve shed regression: the deadline/capacity "
                    "poison requests were not shed (a scheduler path "
                    "raised or silently dropped them?)")
    p99 = float(_field(rows["serve_burst_open"], "p99_ttft_ms"))
    if p99 > MAX_BURST_P99_TTFT_MS:
        errs.append(f"burst-serve p99 TTFT insane: {p99:.0f} ms > "
                    f"{MAX_BURST_P99_TTFT_MS:.0f} ms on the open-loop "
                    "workload")
    return errs


def check_burst_baseline(path: str, base_path: str) -> list:
    """serve-burst's own baseline gates, beyond the generic
    BASELINE_GATES sweep: p99 TTFT is wall clock (wide ratio +
    absolute floor), goodput/shed-rate are status-determined (tight,
    additive eps for the zero-shed baseline)."""
    rows, base = _rows(path), _rows(base_path)
    errs = []
    cur, prev = rows["serve_burst_open"], base["serve_burst_open"]
    p99, p99b = (float(_field(r, "p99_ttft_ms")) for r in (cur, prev))
    limit = max(p99b * BURST_TTFT_BASELINE_RATIO,
                BURST_TTFT_ABS_FLOOR_MS)
    if p99 > limit:
        errs.append(f"burst-serve p99 TTFT regressed vs baseline: "
                    f"{p99:.0f} ms > max({p99b:.0f} * "
                    f"{BURST_TTFT_BASELINE_RATIO}, "
                    f"{BURST_TTFT_ABS_FLOOR_MS:.0f}) ms")
    good, goodb = (float(_field(r, "goodput_frac")) for r in (cur, prev))
    if good < goodb * MIN_BURST_GOODPUT_OF_BASE:
        errs.append(f"burst-serve goodput regressed vs baseline: "
                    f"{good:.3f} < {goodb:.3f} * "
                    f"{MIN_BURST_GOODPUT_OF_BASE}")
    shed, shedb = (float(_field(r, "shed_rate")) for r in (cur, prev))
    if shed > shedb + BURST_SHED_RATE_EPS:
        errs.append(f"burst-serve shed rate regressed vs baseline: "
                    f"{shed:.3f} > {shedb:.3f} + {BURST_SHED_RATE_EPS}")
    return errs


def check_kernels_paged(path: str) -> list:
    rows = _rows(path)
    errs = []
    blk = rows["kernels_paged_blocking"]
    small = int(_field(blk, "small_page_kv_steps"))
    wide = int(_field(blk, "full_tile_kv_steps"))
    if small > wide:
        errs.append(f"multi-page blocking regression: page_size=8 x "
                    f"ppb=16 takes {small} KV grid trips vs {wide} at "
                    "page_size=128 — small pages cost grid steps again")
    sm = int(_field(rows["kernels_paged_serve_small"], "steps"))
    wd = int(_field(rows["kernels_paged_serve_wide"], "steps"))
    if sm > wd:
        errs.append(f"paged-serve blocking regression: page_size=8 "
                    f"(ppb=8) took {sm} engine steps vs {wd} at the "
                    "wide-page layout")
    rel = float(_field(rows["kernels_paged_census"], "max_rel_diff"))
    if not rel <= DYNAMIC_HOST_DEVICE_RTOL:
        errs.append(f"fused-census host/device divergence: max rel diff "
                    f"{rel:.3e} > {DYNAMIC_HOST_DEVICE_RTOL} vs "
                    "bit_census_ref of the kernel output")
    cen = rows["kernels_paged_serve_census"]
    extra = int(_field(cen, "extra_dispatches"))
    if extra > MAX_DYNAMIC_EXTRA_DISPATCHES:
        errs.append(f"serving-census dispatch regression: census-on "
                    f"serve took {extra} extra compiled steps (allowed "
                    f"+{MAX_DYNAMIC_EXTRA_DISPATCHES})")
    if _field(cen, "census_nonzero") != "True":
        errs.append("serving-census regression: estimate_energy=True "
                    "folded no measured census on a dense paged serve")
    if _field(cen, "parity") != "True":
        errs.append("paged-serve blocking parity regression: "
                    "completions diverged across page_size/"
                    "pages_per_block layouts or with the census on")
    return errs


def _gate_value(raw: str):
    try:
        return float(raw.rstrip("x"))
    except ValueError:
        return None


def check_baseline(path: str, base_path: str) -> list:
    """Compare one artifact's gated derived fields against the committed
    baseline (see BASELINE_GATES). Rows or fields absent from either
    side are skipped — baselines only tighten, never block, new rows."""
    rows, base = _rows(path), _rows(base_path)
    errs = []
    fname = os.path.basename(base_path)
    for rname, derived in base.items():
        if rname not in rows:
            continue
        for part in derived.split(";"):
            if "=" not in part:
                continue
            key, raw = part.split("=", 1)
            gate = BASELINE_GATES.get(key)
            want = _gate_value(raw)
            if gate is None or want is None:
                continue
            try:
                got = _gate_value(_field(rows[rname], key))
            except KeyError:
                continue
            if got is None:
                continue
            if gate == "le" and got > want * BASELINE_COUNT_TOL:
                errs.append(
                    f"{fname}:{rname}:{key} regressed vs baseline: "
                    f"{got:g} > {want:g} * {BASELINE_COUNT_TOL}")
            if gate == "ge" and got < want * BASELINE_RATIO_TOL:
                errs.append(
                    f"{fname}:{rname}:{key} regressed vs baseline: "
                    f"{got:g} < {want:g} * {BASELINE_RATIO_TOL}")
    return errs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json-dir", default=".")
    ap.add_argument("--baseline-dir",
                    default=os.path.join(os.path.dirname(__file__),
                                         "baselines"))
    args = ap.parse_args()

    checks = [("BENCH_explorer_pop.json", check_explorer),
              ("BENCH_explorer-dynamic.json", check_explorer_dynamic),
              ("BENCH_serve.json", check_serve),
              ("BENCH_serve-prefill.json", check_serve_prefill),
              ("BENCH_serve-paged.json", check_serve_paged),
              ("BENCH_serve-spec.json", check_serve_spec),
              ("BENCH_serve-policy.json", check_serve_policy),
              ("BENCH_serve-async.json", check_serve_async),
              ("BENCH_serve-burst.json", check_serve_burst),
              ("BENCH_kernels-paged.json", check_kernels_paged)]
    errs = []
    for fname, fn in checks:
        path = os.path.join(args.json_dir, fname)
        if not os.path.exists(path):
            errs.append(f"missing artifact {fname} — did benchmarks.run "
                        "--only explorer,serve,kernels-paged succeed?")
            continue
        errs.extend(fn(path))
        base = os.path.join(args.baseline_dir, fname)
        if os.path.exists(base):
            errs.extend(check_baseline(path, base))
            if fname == "BENCH_serve-burst.json":
                errs.extend(check_burst_baseline(path, base))

    if errs:
        for e in errs:
            print(f"[check_smoke] FAIL: {e}", file=sys.stderr)
        raise SystemExit(1)
    print("[check_smoke] OK: dispatch counts, Pareto parity, dynamic-"
          "energy host/device agreement, serve/chunked-prefill/paged "
          "speedups, multi-page blocking + fused-census gates and the "
          "baseline comparison within bounds")


if __name__ == "__main__":
    main()
