"""Continuous-batching vs. wave decode engine on a skewed prompt-length
workload.

Wave batching pays for skew: a wave runs until its longest request
drains, so 8 slots serving 7 short prompts and 1 long one idle most of
their capacity. Continuous batching refills a retired slot from the
queue mid-flight, so throughput tracks total work, not per-wave maxima.

Measures, on a tiny dense transformer (8 slots, CPU):

* wall-clock tokens/sec for both engines on the same skewed workload,
* slot occupancy (active slot-steps / total slot-steps), and
* that per-request completions are identical under greedy decoding.

Rows follow the harness convention: (name, us_per_call, derived).
"""
from __future__ import annotations

import time
from typing import List, Tuple


def _skewed_prompts(n: int, vocab: int) -> List[List[int]]:
    """7-of-8 short prompts, 1-of-8 long — the skew that starves waves."""
    prompts = []
    for i in range(n):
        length = 96 if i % 8 == 0 else 4
        prompts.append([(7 * i + 3 + j) % vocab for j in range(length)])
    return prompts


def serve_throughput(full: bool = False) -> List[Tuple[str, float, str]]:
    import jax
    from repro.configs import get_arch
    from repro.models import build_model
    from repro.serve import DecodeEngine, ServeConfig

    cfg = get_arch("codeqwen1.5-7b").reduced(n_layers=2, d_model=64,
                                             d_ff=128, vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    n_req = 48 if full else 24
    max_new = 16
    prompts = _skewed_prompts(n_req, cfg.vocab_size)

    engines = {}
    for name in ("wave", "continuous"):
        eng = DecodeEngine(model, params,
                           ServeConfig(max_len=160, batch_slots=8,
                                       engine=name))
        eng.generate(prompts[:8], max_new_tokens=2)   # compile warmup
        engines[name] = eng

    results = {}
    for name, eng in engines.items():
        t0 = time.perf_counter()
        outs = eng.generate(prompts, max_new_tokens=max_new)
        dt = time.perf_counter() - t0
        results[name] = dict(outs=outs, us=dt * 1e6,
                             toks_per_s=eng.stats.tokens_out / dt,
                             occupancy=eng.stats.occupancy,
                             steps=eng.stats.steps)

    wave, cont = results["wave"], results["continuous"]
    speedup = cont["toks_per_s"] / max(wave["toks_per_s"], 1e-9)
    parity = cont["outs"] == wave["outs"]

    return [
        ("serve_continuous", cont["us"],
         f"toks_per_s={cont['toks_per_s']:.1f};"
         f"occupancy={cont['occupancy']:.3f};steps={cont['steps']}"),
        ("serve_wave", wave["us"],
         f"toks_per_s={wave['toks_per_s']:.1f};"
         f"occupancy={wave['occupancy']:.3f};steps={wave['steps']}"),
        ("serve_speedup", 0.0,
         f"speedup={speedup:.2f}x;parity={parity};n_requests={n_req}"),
    ]


if __name__ == "__main__":
    for name, us, derived in serve_throughput():
        print(f"{name},{us:.0f},{derived}")
