"""Continuous-batching vs. wave decode engine on a skewed prompt-length
workload.

Wave batching pays for skew: a wave runs until its longest request
drains, so 8 slots serving 7 short prompts and 1 long one idle most of
their capacity. Continuous batching refills a retired slot from the
queue mid-flight, so throughput tracks total work, not per-wave maxima.

Measures, on a tiny dense transformer (8 slots, CPU):

* wall-clock tokens/sec for both engines on the same skewed workload,
* slot occupancy (active slot-steps / total slot-steps), and
* that per-request completions are identical under greedy decoding.

``serve_prefill`` additionally benchmarks chunked vs streaming prefill
(mean time-to-first-token, prefill tokens/sec) inside the continuous
engine — the ``--only serve-prefill`` bench.

Rows follow the harness convention: (name, us_per_call, derived).
"""
from __future__ import annotations

import time
from typing import List, Tuple


def _skewed_prompts(n: int, vocab: int) -> List[List[int]]:
    """7-of-8 short prompts, 1-of-8 long — the skew that starves waves."""
    prompts = []
    for i in range(n):
        length = 96 if i % 8 == 0 else 4
        prompts.append([(7 * i + 3 + j) % vocab for j in range(length)])
    return prompts


def serve_throughput(full: bool = False) -> List[Tuple[str, float, str]]:
    import jax
    from repro.configs import get_arch
    from repro.models import build_model
    from repro.serve import DecodeEngine, ServeConfig

    cfg = get_arch("codeqwen1.5-7b").reduced(n_layers=2, d_model=64,
                                             d_ff=128, vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    n_req = 48 if full else 24
    max_new = 16
    prompts = _skewed_prompts(n_req, cfg.vocab_size)

    engines = {}
    for name in ("wave", "continuous"):
        eng = DecodeEngine(model, params,
                           ServeConfig(max_len=160, batch_slots=8,
                                       engine=name))
        eng.generate(prompts[:8], max_new_tokens=2)   # compile warmup
        engines[name] = eng

    results = {}
    for name, eng in engines.items():
        t0 = time.perf_counter()
        outs = eng.generate(prompts, max_new_tokens=max_new)
        dt = time.perf_counter() - t0
        results[name] = dict(outs=outs, us=dt * 1e6,
                             toks_per_s=eng.stats.tokens_out / dt,
                             occupancy=eng.stats.occupancy,
                             steps=eng.stats.steps)

    wave, cont = results["wave"], results["continuous"]
    speedup = cont["toks_per_s"] / max(wave["toks_per_s"], 1e-9)
    parity = cont["outs"] == wave["outs"]

    return [
        ("serve_continuous", cont["us"],
         f"toks_per_s={cont['toks_per_s']:.1f};"
         f"occupancy={cont['occupancy']:.3f};steps={cont['steps']}"),
        ("serve_wave", wave["us"],
         f"toks_per_s={wave['toks_per_s']:.1f};"
         f"occupancy={wave['occupancy']:.3f};steps={wave['steps']}"),
        ("serve_speedup", 0.0,
         f"speedup={speedup:.2f}x;parity={parity};n_requests={n_req}"),
    ]


def _long_prompts(n: int, vocab: int) -> List[List[int]]:
    """Long-prompt skew (96 / 48 tokens): the workload where
    time-to-first-token is prefill-bound and chunking pays."""
    prompts = []
    for i in range(n):
        length = 96 if i % 4 == 0 else 48
        prompts.append([(7 * i + 3 + j) % vocab for j in range(length)])
    return prompts


def serve_prefill(full: bool = False) -> List[Tuple[str, float, str]]:
    """Chunked vs streaming prefill on a skewed long-prompt workload:
    mean time-to-first-token, prefill tokens/sec, and greedy parity
    against the wave reference.

    Streaming prefill pays one compiled dispatch per prompt token, so
    TTFT on a 48/96-token prompt is 48-96 step times; chunked prefill
    ingests 32-token blocks through the flash kernel's ``q_start`` path,
    cutting that to 2-3 dispatches of the same total FLOPs. (A chunk
    step costs more wall-clock than a (B, 1) decode step, which is why
    the TTFT win is measured on prefill-heavy prompts — short-prompt
    skew is ``serve_throughput``'s story, where chunking still collapses
    total steps 6x.)
    """
    import jax
    from repro.configs import get_arch
    from repro.models import build_model
    from repro.serve import DecodeEngine, ServeConfig

    cfg = get_arch("codeqwen1.5-7b").reduced(n_layers=2, d_model=64,
                                             d_ff=128, vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    n_req = 32 if full else 16
    max_new = 8                      # prefill-dominated: TTFT is the story
    prompts = _long_prompts(n_req, cfg.vocab_size)

    engines = {}
    for name, engine, chunk in (("streaming", "continuous", 1),
                                ("chunked", "continuous", 32),
                                ("wave", "wave", 1)):
        eng = DecodeEngine(model, params,
                           ServeConfig(max_len=160, batch_slots=8,
                                       engine=engine,
                                       prefill_chunk=chunk))
        eng.generate(prompts[:8], max_new_tokens=2)   # compile warmup
        engines[name] = eng

    results = {}
    for name, eng in engines.items():
        t0 = time.perf_counter()
        outs = eng.generate(prompts, max_new_tokens=max_new)
        dt = time.perf_counter() - t0
        results[name] = dict(
            outs=outs, us=dt * 1e6,
            ttft_us=eng.stats.mean_ttft_s * 1e6,
            prefill_toks_per_s=eng.stats.prefill_tokens / dt,
            prefill_steps=eng.stats.prefill_steps,
            steps=eng.stats.steps)

    st, ch, wv = (results[k] for k in ("streaming", "chunked", "wave"))
    ttft_speedup = st["ttft_us"] / max(ch["ttft_us"], 1e-9)
    parity = ch["outs"] == wv["outs"] and st["outs"] == wv["outs"]

    return [
        ("serve_prefill_chunked", ch["us"],
         f"mean_ttft_us={ch['ttft_us']:.0f};"
         f"prefill_toks_per_s={ch['prefill_toks_per_s']:.1f};"
         f"prefill_steps={ch['prefill_steps']};steps={ch['steps']}"),
        ("serve_prefill_streaming", st["us"],
         f"mean_ttft_us={st['ttft_us']:.0f};"
         f"prefill_toks_per_s={st['prefill_toks_per_s']:.1f};"
         f"prefill_steps={st['prefill_steps']};steps={st['steps']}"),
        ("serve_prefill_speedup", 0.0,
         f"ttft_speedup={ttft_speedup:.2f}x;parity={parity};"
         f"n_requests={n_req}"),
    ]


def serve_paged(full: bool = False) -> List[Tuple[str, float, str]]:
    """Paged KV pool + packed ragged prefill vs the PR-4 rectangle path,
    at **fixed KV memory**.

    The contiguous engine reserves ``slots x max_len`` tokens of KV per
    wave of residency, so its concurrency is capped at ``batch_slots``
    no matter how short the requests are. The paged engine is given the
    *same* pool (``slots x max_len / page_size`` pages) but 4x the
    slots: short requests reserve only ``ceil((tail+budget)/page_size)``
    pages, so many more run concurrently, prefill packs into one
    (ΣC,) stream instead of padding a (B, C) rectangle, and the step
    count collapses. Gates (check_smoke): >= 1.3x tokens/sec, >= 2x
    peak concurrent requests, identical greedy completions, resident
    pages never above the pool.
    """
    import jax
    from repro.configs import get_arch
    from repro.models import build_model
    from repro.serve import DecodeEngine, ServeConfig

    cfg = get_arch("codeqwen1.5-7b").reduced(n_layers=2, d_model=64,
                                             d_ff=128, vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    n_req = 48 if full else 24
    max_new = 16
    page_size = 16
    slots, max_len = 8, 160
    pool = slots * max_len // page_size          # same KV token budget
    prompts = _skewed_prompts(n_req, cfg.vocab_size)

    engines = {
        "rect": DecodeEngine(model, params, ServeConfig(
            max_len=max_len, batch_slots=slots, engine="continuous")),
        "paged": DecodeEngine(model, params, ServeConfig(
            max_len=max_len, batch_slots=4 * slots, engine="continuous",
            page_size=page_size, kv_pages=pool, pack_tokens=256)),
    }
    for eng in engines.values():
        # full-workload warmup: the packed step is width-bucketed, so a
        # truncated warmup would leave per-bucket compilations inside
        # the timed run
        eng.generate(prompts, max_new_tokens=max_new)

    results = {}
    for name, eng in engines.items():
        t0 = time.perf_counter()
        outs = eng.generate(prompts, max_new_tokens=max_new)
        dt = time.perf_counter() - t0
        results[name] = dict(outs=outs, us=dt * 1e6,
                             toks_per_s=eng.stats.tokens_out / dt,
                             ttft_us=eng.stats.mean_ttft_s * 1e6,
                             steps=eng.stats.steps,
                             peak_pages=eng.stats.peak_resident_pages,
                             pool=eng.stats.pool_pages,
                             peak_active=eng.stats.peak_active_requests)

    rect, paged = results["rect"], results["paged"]
    speedup = paged["toks_per_s"] / max(rect["toks_per_s"], 1e-9)
    concurrency = paged["peak_active"] / max(slots, 1)
    parity = paged["outs"] == rect["outs"]

    return [
        ("serve_paged", paged["us"],
         f"toks_per_s={paged['toks_per_s']:.1f};"
         f"steps={paged['steps']};mean_ttft_us={paged['ttft_us']:.0f};"
         f"peak_pages={paged['peak_pages']};pool={paged['pool']};"
         f"peak_active={paged['peak_active']}"),
        ("serve_paged_rect", rect["us"],
         f"toks_per_s={rect['toks_per_s']:.1f};steps={rect['steps']};"
         f"mean_ttft_us={rect['ttft_us']:.0f};slots={slots}"),
        ("serve_paged_speedup", 0.0,
         f"speedup={speedup:.2f}x;concurrency={concurrency:.2f}x;"
         f"parity={parity};n_requests={n_req}"),
    ]


def _family_parity(bits: int, k: int) -> bool:
    """Exact greedy parity spec vs non-spec on tiny models of all five
    assigned families (dense / ssm / hybrid / encdec / moe)."""
    import jax
    from repro.configs import get_arch
    from repro.models import build_model
    from repro.serve import DecodeEngine, ServeConfig, SpecConfig

    prompts = [[5, 9, 2, 7], [1, 2], [3] * 12, [4, 5, 6], [7], [13, 14]]
    for arch in ("codeqwen1.5-7b", "xlstm-1.3b", "zamba2-7b",
                 "seamless-m4t-medium", "granite-moe-1b-a400m"):
        cfg = get_arch(arch).reduced(n_layers=2, d_model=32, d_ff=64,
                                     vocab=64)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        base = ServeConfig(max_len=48, batch_slots=2, engine="continuous",
                          prefill_chunk=4, page_size=8,
                          debug_invariants=True)
        ref = DecodeEngine(model, params, base).generate(
            prompts, max_new_tokens=6)
        spec_cfg = ServeConfig(max_len=48, batch_slots=2,
                              engine="continuous", prefill_chunk=4,
                              page_size=8, debug_invariants=True,
                              spec=SpecConfig(k=k, drafter_bits=bits))
        out = DecodeEngine(model, params, spec_cfg).generate(
            prompts, max_new_tokens=6)
        if out != ref:
            return False
    return True


def serve_spec(full: bool = False) -> List[Tuple[str, float, str]]:
    """Speculative decoding with the NEAT reduced-precision drafter vs
    the PR-5 paged engine, on a decode-heavy skewed workload.

    The drafter is the serving model itself under a ``WholeProgram
    (MantissaTrunc(bits))`` rule plus mantissa-truncated weight views: a
    fused k-step ``lax.scan`` proposes k greedy tokens per decoding slot
    through the *shared* KV pages, then the target verifies the k+1-row
    window in one packed chunk-path dispatch — so each accepted window
    emits up to k+1 tokens for 2 dispatches instead of 1 per dispatch.
    Greedy completions are byte-identical to the non-speculative engine
    (the emitted tokens are always the target's own argmax); acceptance
    degrades as drafter bits shrink, which is the tradeoff
    ``explore_serving`` searches. Gates (check_smoke): >= 1.5x
    tokens/sec over the non-speculative paged baseline at bits=10 with
    acceptance >= 0.6, exact parity on this workload AND on tiny models
    of all five families, and a bounded p99 TTFT tail.
    """
    import time as _t

    import jax
    from repro.configs import get_arch
    from repro.models import build_model
    from repro.serve import DecodeEngine, ServeConfig, SpecConfig

    cfg = get_arch("codeqwen1.5-7b").reduced(n_layers=2, d_model=64,
                                             d_ff=128, vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    n_req = 48 if full else 24
    max_new = 32                     # decode-heavy: speculation's regime
    page_size = 16
    slots, max_len = 8, 160
    pool = 4 * slots * max_len // page_size
    spec_k = 4
    prompts = _skewed_prompts(n_req, cfg.vocab_size)

    def paged_cfg(spec=None):
        return ServeConfig(max_len=max_len, batch_slots=4 * slots,
                          engine="continuous", page_size=page_size,
                          kv_pages=pool, pack_tokens=256, spec=spec)

    arms = {"base": DecodeEngine(model, params, paged_cfg())}
    for bits in (4, 8, 10):
        arms[f"b{bits}"] = DecodeEngine(
            model, params,
            paged_cfg(SpecConfig(k=spec_k, drafter_bits=bits)))
    for eng in arms.values():
        eng.generate(prompts, max_new_tokens=max_new)  # full warmup

    results = {}
    for name, eng in arms.items():
        t0 = _t.perf_counter()
        outs = eng.generate(prompts, max_new_tokens=max_new)
        dt = _t.perf_counter() - t0
        st = eng.stats
        results[name] = dict(
            outs=outs, us=dt * 1e6, toks_per_s=st.tokens_out / dt,
            steps=st.steps, acceptance=st.acceptance_rate,
            windows=st.spec_windows, accepted=st.accepted_tokens,
            p50_ms=st.p50_ttft_s * 1e3, p99_ms=st.p99_ttft_s * 1e3)

    base = results["base"]
    best = results["b10"]
    speedup = best["toks_per_s"] / max(base["toks_per_s"], 1e-9)
    parity = all(results[a]["outs"] == base["outs"]
                 for a in ("b4", "b8", "b10"))
    ttft_ratio = best["p99_ms"] / max(base["p99_ms"], 1e-9)
    fam_parity = _family_parity(bits=10, k=3)

    rows = [("serve_spec_base", base["us"],
             f"toks_per_s={base['toks_per_s']:.1f};steps={base['steps']};"
             f"p50_ttft_ms={base['p50_ms']:.1f};"
             f"p99_ttft_ms={base['p99_ms']:.1f}")]
    for bits in (4, 8, 10):
        r = results[f"b{bits}"]
        rows.append((f"serve_spec_b{bits}", r["us"],
                     f"toks_per_s={r['toks_per_s']:.1f};"
                     f"steps={r['steps']};"
                     f"acceptance={r['acceptance']:.3f};"
                     f"windows={r['windows']};"
                     f"accepted={r['accepted']};"
                     f"p50_ttft_ms={r['p50_ms']:.1f};"
                     f"p99_ttft_ms={r['p99_ms']:.1f}"))
    rows.append(("serve_spec_speedup", 0.0,
                 f"speedup={speedup:.2f}x;"
                 f"acceptance={best['acceptance']:.3f};"
                 f"parity={parity};families_parity={fam_parity};"
                 f"ttft_p99_ratio={ttft_ratio:.2f}x;"
                 f"n_requests={n_req};k={spec_k}"))
    return rows


def serve_policy(full: bool = False) -> List[Tuple[str, float, str]]:
    """Phase/layer precision policies as the serving surface, vs the
    PR-6 uniform-drafter baseline, on the skewed speculative workload.

    Three precision arms over the same paged speculative engine:

    * **base** — the PR-6 entry point, ``SpecConfig(drafter_bits=10)``
      (a whole-program uniform drafter, now folded into a one-phase
      policy by the engine);
    * **uniform** — the best whole-program uniform drafter from an
      explicit bits grid (``PrecisionPolicy.drafter(b)``, the PR-6
      grid), best = lowest *measured* pJ/token (the fused kernel-census
      token-stream energy, PR 8);
    * **hetero** — the best phase/layer-heterogeneous policy found by
      ``explore(objectives="serving")`` over the (phase, site [+
      default]) genome, *re-served from its serialized*
      ``payload["policy"]`` *artifact* — the exact file
      ``launch/serve.py --policy`` consumes.

    Headline gates (check_smoke): among policies holding the
    MIN_POLICY_ACCEPTANCE SLA floor, the hetero policy's measured
    pJ/token beats the best grid uniform's (per-site placement beats
    the whole-program diagonal at the acceptance SLA, the paper's
    claim measured end to end in the engine); it beats the PR-6
    baseline's measured pJ/token by >= MIN_POLICY_ENERGY_REDUCTION;
    the explored measured front is non-degenerate (>= 2 distinct
    positive fused-census energies across the points); greedy
    completions stay byte-identical across every arm (speculative
    emission is the target's own argmax, so precision only moves
    acceptance/energy, never outputs); and p99 TTFT stays bounded. A
    fourth arm serves SLA tiers ({exact: mant24, turbo: hetero} over a
    split slot budget) and gates that the exact tier is byte-identical
    to non-policy serving while the turbo tier's pJ/token stays below
    the exact tier's.
    """
    import time as _t

    import jax
    from repro.configs import get_arch
    from repro.core import ServingTask, explore
    from repro.models import build_model
    from repro.serve import (DecodeEngine, PrecisionPolicy, ServeConfig,
                             SpecConfig)

    cfg = get_arch("codeqwen1.5-7b").reduced(n_layers=2, d_model=64,
                                             d_ff=128, vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    n_req = 32 if full else 16
    max_new = 16
    page_size = 16
    slots, max_len = 8, 160
    spec_k = 4
    prompts = _skewed_prompts(n_req, cfg.vocab_size)

    def serve_cfg(spec=None, tiers=None, energy=True):
        return ServeConfig(max_len=max_len, batch_slots=slots,
                           engine="continuous", page_size=page_size,
                           spec=spec, tiers=tiers, estimate_energy=energy)

    def timed(eng, tiers=None):
        eng.generate(prompts, max_new_tokens=max_new, tiers=tiers)
        t0 = _t.perf_counter()
        outs = eng.generate(prompts, max_new_tokens=max_new, tiers=tiers)
        dt = _t.perf_counter() - t0
        st = eng.stats
        return dict(outs=outs, us=dt * 1e6,
                    toks_per_s=st.tokens_out / dt,
                    acceptance=st.acceptance_rate,
                    pj_tok=st.est_pj_per_token,
                    measured=st.measured_pj_per_token,
                    p50_ms=st.p50_ttft_s * 1e3,
                    p99_ms=st.p99_ttft_s * 1e3, stats=st)

    # -- arm 1: PR-6 baseline, the deprecated uniform-drafter knob
    base = timed(DecodeEngine(
        model, params,
        serve_cfg(spec=SpecConfig(k=spec_k, drafter_bits=10))))

    # -- arm 2: best whole-program uniform from the PR-6 bits grid;
    # "best" = lowest *measured* pJ/token (the fused-census token-stream
    # energy — the explorer's serving energy axis since PR 8) among the
    # bits that hold the SLA acceptance floor (check_smoke's
    # MIN_POLICY_ACCEPTANCE): the serving question is "cheapest energy
    # subject to the acceptance SLA", not energy at any acceptance
    acc_floor = 0.9
    grid = {}
    for bits in (4, 6, 8, 10, 24):
        eng = DecodeEngine(model, params, serve_cfg(SpecConfig(k=spec_k)),
                           policy=PrecisionPolicy.drafter(bits))
        eng.generate(prompts, max_new_tokens=max_new)
        st = eng.stats
        grid[bits] = dict(acceptance=st.acceptance_rate,
                          pj_tok=st.est_pj_per_token,
                          measured=st.measured_pj_per_token)
    qualifying = [b for b in grid if grid[b]["acceptance"] >= acc_floor]
    best_bits = min(qualifying or grid,
                    key=lambda b: grid[b]["measured"])
    best_u = grid[best_bits]

    # -- arm 3: hetero policy from the serving explorer, re-served
    # from its serialized policy artifact
    rep = explore(
        ServingTask(model, params, prompts, serve_cfg(energy=False),
                    max_new_tokens=max_new, k=spec_k, phases=("draft",),
                    family="plc", n_sites=4, pop_size=16, n_gen=2,
                    max_evals=(40 if full else 24), name="serve-policy"),
        objectives="serving")
    # p.energy is the *measured* token-stream census since PR 8, so the
    # placement gate compares measured-to-measured against the grid:
    # among policies holding the acceptance SLA floor, per-site
    # placement must serve cheaper than every whole-program uniform
    cands = [p for p in rep.points
             if not p.payload["uniform"]
             and p.payload["acceptance"] >= acc_floor
             and p.energy < best_u["measured"]]
    hetero_beats = bool(cands)
    best_p = (min(cands, key=lambda p: p.energy) if cands
              else min(rep.points, key=lambda p: p.energy))
    # measured front non-degenerate: every explored point carries a
    # positive fused-census energy and the front actually spreads
    measured_vals = {round(p.payload["measured_pj_per_token"], 6)
                     for p in rep.points}
    measured_front = (len(measured_vals) >= 2
                      and all(v > 0 for v in measured_vals))
    hetero_pol = PrecisionPolicy.from_dict(best_p.payload["policy"])
    hetero = timed(DecodeEngine(model, params,
                                serve_cfg(SpecConfig(k=spec_k)),
                                policy=hetero_pol))

    # -- arm 4: SLA tiers — exact requests byte-identical at mant24,
    # the rest on the explored hetero policy, one engine
    tier_names = ["exact", "turbo"]
    tiered_eng = DecodeEngine(
        model, params,
        serve_cfg(SpecConfig(k=spec_k),
                  tiers={"exact": PrecisionPolicy.uniform(24, name="exact"),
                         "turbo": hetero_pol}))
    ask = [tier_names[i % 2] for i in range(n_req)]
    tiered = timed(tiered_eng, tiers=ask)
    ref = DecodeEngine(model, params,
                       serve_cfg(spec=None, energy=False)).generate(
        prompts, max_new_tokens=max_new)
    exact_parity = all(tiered["outs"][i] == ref[i]
                       for i in range(n_req) if ask[i] == "exact")
    tst = tiered["stats"]
    exact_pj = tst.per_tier["exact"].est_pj_per_token
    turbo_pj = tst.per_tier["turbo"].est_pj_per_token
    exact_m = tst.per_tier["exact"].measured_pj_per_token
    turbo_m = tst.per_tier["turbo"].measured_pj_per_token

    parity = (base["outs"] == ref and hetero["outs"] == ref
              and exact_parity and turbo_pj < exact_pj
              and turbo_m < exact_m)
    energy_reduction = base["measured"] / max(hetero["measured"], 1e-9)
    est_reduction = base["pj_tok"] / max(hetero["pj_tok"], 1e-9)
    ttft_ratio = hetero["p99_ms"] / max(base["p99_ms"], 1e-9)
    genome = "-".join(str(b) for b in best_p.payload["genome"])

    return [
        ("serve_policy_base", base["us"],
         f"toks_per_s={base['toks_per_s']:.1f};"
         f"acceptance={base['acceptance']:.3f};"
         f"pj_per_tok={base['pj_tok']:.4e};"
         f"measured_pj_per_tok={base['measured']:.4e};"
         f"p99_ttft_ms={base['p99_ms']:.1f}"),
        ("serve_policy_uniform", 0.0,
         f"best_bits={best_bits};"
         f"acceptance={best_u['acceptance']:.3f};"
         f"pj_per_tok={best_u['pj_tok']:.4e};"
         f"measured_pj_per_tok={best_u['measured']:.4e};"
         f"grid={'/'.join(str(b) for b in grid)}"),
        ("serve_policy_hetero", hetero["us"],
         f"toks_per_s={hetero['toks_per_s']:.1f};"
         f"acceptance={hetero['acceptance']:.3f};"
         f"pj_per_tok={hetero['pj_tok']:.4e};"
         f"measured_pj_per_tok={hetero['measured']:.4e};"
         f"genome={genome};n_evals={rep.n_evals};"
         f"p99_ttft_ms={hetero['p99_ms']:.1f}"),
        ("serve_policy_tiered", tiered["us"],
         f"exact_parity={exact_parity};"
         f"exact_pj_per_tok={exact_pj:.4e};"
         f"turbo_pj_per_tok={turbo_pj:.4e};"
         f"exact_measured_pj_per_tok={exact_m:.4e};"
         f"turbo_measured_pj_per_tok={turbo_m:.4e};"
         f"downgraded={tst.downgraded};"
         f"p99_ttft_ms={tiered['p99_ms']:.1f}"),
        ("serve_policy_gate", 0.0,
         f"hetero_beats_uniform={hetero_beats};"
         f"energy_reduction={energy_reduction:.3f}x;"
         f"est_energy_reduction={est_reduction:.3f}x;"
         f"measured_front={measured_front};"
         f"measured_front_distinct={len(measured_vals)};"
         f"acceptance={hetero['acceptance']:.3f};"
         f"parity={parity};"
         f"ttft_p99_ratio={ttft_ratio:.2f}x;"
         f"n_requests={n_req};k={spec_k}"),
    ]


def _megastep_family_parity(sync_every: int) -> bool:
    """Byte-identical greedy completions, fused megasteps vs the
    single-step loop, on tiny models of all five assigned families
    (paged KV where the family pages)."""
    import jax
    from repro.configs import get_arch
    from repro.models import build_model
    from repro.serve import DecodeEngine, ServeConfig

    prompts = [[5, 9, 2, 7], [1, 2], [3] * 12, [4, 5, 6], [7], [13, 14]]
    for arch in ("codeqwen1.5-7b", "xlstm-1.3b", "zamba2-7b",
                 "seamless-m4t-medium", "granite-moe-1b-a400m"):
        cfg = get_arch(arch).reduced(n_layers=2, d_model=32, d_ff=64,
                                     vocab=64)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))

        def serve(n):
            return DecodeEngine(model, params, ServeConfig(
                max_len=48, batch_slots=2, engine="continuous",
                prefill_chunk=4, page_size=8, sync_every=n,
                debug_invariants=True)).generate(prompts,
                                                 max_new_tokens=6)
        if serve(sync_every) != serve(1):
            return False
    return True


def serve_async(full: bool = False) -> List[Tuple[str, float, str]]:
    """Fused decode megasteps: sync_every ∈ {1, 8, 32} on a
    decode-dominated workload (short prompts, long completions — the
    regime where the per-token host round trip is the bottleneck).

    Gated downstream (``check_smoke.check_serve_async``): tokens/sec at
    sync_every=32 must beat sync_every=1 by >= MIN_ASYNC_SPEEDUP, host
    syncs must drop to steps/sync_every plus scheduling events, greedy
    completions must stay byte-identical (all five families), and the
    measured fused-census pJ/token must equal the single-step path.
    """
    import jax
    from repro.configs import get_arch
    from repro.models import build_model
    from repro.serve import DecodeEngine, ServeConfig

    cfg = get_arch("codeqwen1.5-7b").reduced(n_layers=2, d_model=64,
                                             d_ff=128, vocab=128)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    n_req = 32 if full else 16
    max_new = 48
    slots = 8
    # decode-heavy: 4–8 token prompts, uniform 48-token completions
    prompts = [[(7 * i + 3 + j) % cfg.vocab_size
                for j in range(4 + i % 5)] for i in range(n_req)]

    def build(n, energy=False):
        return DecodeEngine(model, params, ServeConfig(
            max_len=64, batch_slots=slots, engine="continuous",
            prefill_chunk=8, sync_every=n, estimate_energy=energy))

    results = {}
    for n in (1, 8, 32):
        eng = build(n)
        eng.generate(prompts[:slots], max_new_tokens=4)   # compile warmup
        t0 = time.perf_counter()
        outs = eng.generate(prompts, max_new_tokens=max_new)
        dt = time.perf_counter() - t0
        st = eng.stats
        results[n] = dict(outs=outs, us=dt * 1e6,
                          toks_per_s=st.tokens_out / dt, stats=st)

    s1, s32 = results[1]["stats"], results[32]["stats"]
    speedup = results[32]["toks_per_s"] / max(results[1]["toks_per_s"],
                                              1e-9)
    parity = (results[8]["outs"] == results[1]["outs"]
              and results[32]["outs"] == results[1]["outs"])
    # deterministic sync bound: one pull per fused window or scheduling
    # step — ceil(steps/32) decode windows plus prefill steps and one
    # flush window per retirement
    bound = (-(-s32.steps // 32) + s32.prefill_steps + n_req)
    sync_bound = s32.host_syncs <= bound
    # measured fused-census parity, megastep vs single-step
    c1 = build(1, energy=True)
    c32 = build(32, energy=True)
    c1.generate(prompts, max_new_tokens=max_new)
    c32.generate(prompts, max_new_tokens=max_new)
    m1 = c1.stats.measured_pj_per_token
    m32 = c32.stats.measured_pj_per_token
    census_rel = abs(m32 - m1) / max(abs(m1), 1e-12)
    fam_parity = _megastep_family_parity(8)

    rows = []
    for n in (1, 8, 32):
        st = results[n]["stats"]
        rows.append((f"serve_async_sync{n}", results[n]["us"],
                     f"toks_per_s={results[n]['toks_per_s']:.1f};"
                     f"steps={st.steps};host_syncs={st.host_syncs};"
                     f"megasteps={st.megasteps};"
                     f"dispatch_wait_ms={st.dispatch_wait_s * 1e3:.1f};"
                     f"host_sched_ms={st.host_sched_s * 1e3:.1f};"
                     f"p50_tok_lat_ms={st.p50_tok_lat_s * 1e3:.3f};"
                     f"p99_tok_lat_ms={st.p99_tok_lat_s * 1e3:.3f}"))
    rows.append(("serve_async_speedup", 0.0,
                 f"speedup={speedup:.3f}x;parity={parity};"
                 f"families_parity={fam_parity};"
                 f"sync_bound={sync_bound};"
                 f"host_syncs_1={s1.host_syncs};"
                 f"host_syncs_32={s32.host_syncs};"
                 f"census_rel={census_rel:.3e};"
                 f"measured_pj_per_tok={m32:.4e};"
                 f"n_requests={n_req};max_new={max_new}"))
    return rows


def serve_burst(full: bool = False) -> List[Tuple[str, float, str]]:
    """Bursty-traffic hardening: lazy page growth + preemption vs the
    historical worst-case reservation, on a pool deliberately too small
    for the workload's worst case.

    Three claims, gated downstream (``check_smoke.check_serve_burst``):

    * **Reservation** (deterministic): at a fixed pool the lazy+preempt
      engine must hold >= ``MIN_BURST_CONCURRENCY`` x the concurrent
      requests of worst-case reservation, with byte-identical greedy
      completions (both arms, and vs an ample-pool reference) — resident
      KV tracks live tokens, not budgets.
    * **Structured failure** (deterministic): two poison requests — a
      ``deadline_s=0`` TTFT SLA that expires before admission and a
      budget whose worst-case pages exceed the whole pool — must retire
      as ``shed_deadline`` / ``shed_capacity`` statuses while every
      other request completes byte-identically; nothing raises.
    * **Open loop** (wall clock): a seeded Poisson arrival stream
      (``benchmarks.traffic``) with two priority classes reports p99
      TTFT (relative to each request's arrival), goodput fraction,
      shed rate and swap traffic; p99 TTFT is baseline-gated with a
      wide wall-clock tolerance, goodput/shed-rate tightly (they are
      status-determined, not timing-determined).

    ``debug_invariants=True`` on every engine: each scheduler step
    asserts free + resident (+ deferred) == pool and the host-side
    swap ledger matches the queue's restore payloads, so an accounting
    violation fails the bench itself.
    """
    import jax
    from repro.configs import get_arch
    from repro.models import build_model
    from repro.serve import DecodeEngine, ServeConfig

    from benchmarks.traffic import burst_workload

    cfg = get_arch("codeqwen1.5-7b").reduced(n_layers=2, d_model=32,
                                             d_ff=64, vocab=64)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    n_req = 24 if full else 16
    seed = 0
    reqs = burst_workload(n_req, seed=seed)
    prompts = [r.prompt for r in reqs]
    budgets = [r.max_new_tokens for r in reqs]
    prios = [r.priority for r in reqs]
    arrivals = [r.arrival_s for r in reqs]

    slots, ps, pool = 4, 8, 8      # pool < slots * worst-case pages

    def build(reserve: str, preempt: bool, pages: int = pool):
        return DecodeEngine(model, params, ServeConfig(
            max_len=72, batch_slots=slots, engine="continuous",
            prefill_chunk=8, page_size=ps, kv_pages=pages,
            sync_every=8, reserve=reserve, preempt=preempt,
            debug_invariants=True))

    # -- reservation arms: closed loop (all requests at t=0) so peak
    #    concurrency and completions are schedule-deterministic
    ample = build("lazy", True, pages=64)
    ample.generate(prompts[:slots], max_new_tokens=4)   # compile warmup
    ref = ample.generate(prompts, max_new_tokens=budgets,
                         priority=prios)
    worst = build("worst_case", False)
    worst_out = worst.generate(prompts, max_new_tokens=budgets,
                               priority=prios)
    lazy = build("lazy", True)
    lazy_out = lazy.generate(prompts, max_new_tokens=budgets,
                             priority=prios)
    peak_w = worst.stats.peak_active_requests
    peak_l = lazy.stats.peak_active_requests
    gain = peak_l / max(peak_w, 1)
    parity = lazy_out == worst_out == ref

    # -- structured failure: poison the lazy arm with an expired
    #    deadline and an unplaceable budget; the rest must not notice
    poison_prompts = prompts + [[1, 2, 3], [4] * 12]
    poison_budgets = budgets + [8, 64]      # 64: ceil((7+64)/8) = 9 > 8
    poison_dl = [None] * n_req + [0.0, None]
    shed_eng = build("lazy", True)
    shed_out = shed_eng.generate(poison_prompts,
                                 max_new_tokens=poison_budgets,
                                 priority=prios + [0, 0],
                                 deadline_s=poison_dl)
    st = shed_eng.stats
    statuses_ok = (
        st.status.get(n_req) == "shed_deadline"
        and st.status.get(n_req + 1) == "shed_capacity"
        and shed_out[:n_req] == lazy_out
        and shed_out[n_req] == [] and shed_out[n_req + 1] == []
        and all(st.status[i] == "ok" or st.status[i].startswith("preempt")
                for i in range(n_req)))

    # -- open loop: the seeded Poisson stream, arrivals honored
    open_eng = build("lazy", True)
    t0 = time.perf_counter()
    open_eng.generate(prompts, max_new_tokens=budgets, priority=prios,
                      arrival_s=arrivals)
    dt = time.perf_counter() - t0
    so = open_eng.stats
    ttfts = sorted(so.ttft_s[i] - arrivals[i]
                   for i in so.ttft_s)
    p99 = ttfts[min(len(ttfts) - 1,
                    int(0.99 * (len(ttfts) - 1)))] if ttfts else 0.0
    goodput_frac = so.goodput_tokens / max(so.tokens_out, 1)

    return [
        ("serve_burst_open", dt * 1e6,
         f"toks_per_s={so.tokens_out / dt:.1f};"
         f"p99_ttft_ms={p99 * 1e3:.1f};"
         f"goodput_frac={goodput_frac:.3f};"
         f"shed_rate={so.shed_rate:.3f};"
         f"preemptions={so.preemptions};"
         f"swap_mb={(so.swap_out_bytes + so.swap_in_bytes) / 1e6:.3f};"
         f"seed={seed};n_requests={n_req}"),
        ("serve_burst_reservation", 0.0,
         f"concurrency={gain:.2f}x;peak_lazy={peak_l};"
         f"peak_worst={peak_w};parity={parity};"
         f"preemptions={lazy.stats.preemptions};pool={pool};"
         f"pages_worst_case={slots * 5}"),
        ("serve_burst_shed", 0.0,
         f"statuses_ok={statuses_ok};"
         f"shed_deadline={st.shed_deadline};"
         f"shed_capacity={st.shed_capacity};"
         f"goodput_frac={st.goodput_tokens / max(st.tokens_out, 1):.3f};"
         f"invariants=on;no_raise=True"),
    ]


if __name__ == "__main__":
    for name, us, derived in (serve_throughput() + serve_prefill()
                              + serve_paged() + serve_spec()
                              + serve_policy() + serve_async()
                              + serve_burst()):
        print(f"{name},{us:.0f},{derived}")
