"""Benchmark harness: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig05]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale budgets (400 evals per experiment)")
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark names")
    args = ap.parse_args()

    from benchmarks import explorer_bench, lenet_bench, lm_precision
    from benchmarks import paper_figs, roofline_table

    benches = [
        ("explorer_pop", explorer_bench.explorer_population),
        ("fig04", paper_figs.fig04_flop_breakdown),
        ("fig05_06", paper_figs.fig05_06_wp_vs_cip),
        ("fig07", paper_figs.fig07_memory_savings),
        ("fig08", paper_figs.fig08_precision_target),
        ("fig09", paper_figs.fig09_fcs_radar),
        ("table3", paper_figs.table3_robustness),
        ("lenet", lenet_bench.lenet_case_study),
        ("lm_precision", lm_precision.lm_precision),
        ("roofline", roofline_table.roofline_rows),
    ]

    print("name,us_per_call,derived")
    failed = 0
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        try:
            rows = fn(full=args.full)
        except Exception as e:
            failed += 1
            traceback.print_exc(file=sys.stderr)
            print(f"{name},0,ERROR:{type(e).__name__}")
            continue
        for (rname, us, derived) in rows:
            print(f"{rname},{us:.0f},{derived}")
    if failed:
        raise SystemExit(f"{failed} benchmarks failed")


if __name__ == "__main__":
    main()
