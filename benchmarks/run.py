"""Benchmark harness: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV and writes one machine-readable
``BENCH_<name>.json`` per benchmark (CI uploads these as artifacts and
gates on them via ``benchmarks.check_smoke``).

  PYTHONPATH=src python -m benchmarks.run [--full] [--only explorer,serve]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import traceback


def _git_sha() -> str:
    """Provenance stamp for the BENCH artifacts; 'unknown' outside git."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)),
            timeout=10).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale budgets (400 evals per experiment)")
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filters on bench names")
    ap.add_argument("--json-dir", default=".",
                    help="directory for the BENCH_<name>.json artifacts")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload seed recorded in the artifacts (the "
                         "benches are deterministic at a fixed seed)")
    args = ap.parse_args()
    filters = [f for f in (args.only or "").split(",") if f]

    from benchmarks import (explorer_bench, kernels_paged, lenet_bench,
                            lm_precision, paper_figs, roofline_table,
                            serve_bench)

    benches = [
        ("explorer_pop", explorer_bench.explorer_population),
        ("explorer-dynamic", explorer_bench.explorer_dynamic),
        ("kernels-paged", kernels_paged.kernels_paged),
        ("serve", serve_bench.serve_throughput),
        ("serve-prefill", serve_bench.serve_prefill),
        ("serve-paged", serve_bench.serve_paged),
        ("serve-spec", serve_bench.serve_spec),
        ("serve-policy", serve_bench.serve_policy),
        ("serve-async", serve_bench.serve_async),
        ("serve-burst", serve_bench.serve_burst),
        ("fig04", paper_figs.fig04_flop_breakdown),
        ("fig05_06", paper_figs.fig05_06_wp_vs_cip),
        ("fig07", paper_figs.fig07_memory_savings),
        ("fig08", paper_figs.fig08_precision_target),
        ("fig09", paper_figs.fig09_fcs_radar),
        ("table3", paper_figs.table3_robustness),
        ("lenet", lenet_bench.lenet_case_study),
        ("lm_precision", lm_precision.lm_precision),
        ("roofline", roofline_table.roofline_rows),
    ]

    print("name,us_per_call,derived")
    sha = _git_sha()
    failed = 0
    for name, fn in benches:
        if filters and not any(f in name for f in filters):
            continue
        try:
            rows = fn(full=args.full)
        except Exception as e:
            failed += 1
            traceback.print_exc(file=sys.stderr)
            print(f"{name},0,ERROR:{type(e).__name__}")
            continue
        for (rname, us, derived) in rows:
            print(f"{rname},{us:.0f},{derived}")
        path = os.path.join(args.json_dir, f"BENCH_{name}.json")
        with open(path, "w") as f:
            json.dump({"name": name, "full": args.full,
                       "git_sha": sha, "seed": args.seed,
                       "rows": [[r, us, d] for r, us, d in rows]},
                      f, indent=2)
    if failed:
        raise SystemExit(f"{failed} benchmarks failed")


if __name__ == "__main__":
    main()
