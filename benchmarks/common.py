"""Shared helpers for the per-figure benchmarks."""
from __future__ import annotations

import time
from typing import Callable, Dict, Tuple

from repro.apps import get_app, make_task
from repro.core import explore

# modest budgets so the whole harness runs on one CPU core; the paper's
# full budget (400 evals) is used by passing full=True
FAST = dict(pop_size=14, n_gen=4, max_evals=70)
FULL = dict(pop_size=40, n_gen=9, max_evals=400)

APPS_F32 = ("blackscholes", "kmeans", "radar", "fluidanimate", "heartwall")


def budget(full: bool) -> Dict:
    return dict(FULL if full else FAST)


def timed(fn: Callable, *args, **kwargs) -> Tuple[float, object]:
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    return (time.perf_counter() - t0) * 1e6, out


def explore_app(name: str, family: str, *, full: bool = False, seed: int = 0,
                n_train: int = 3, n_test: int = 2, n_sites: int = 10,
                robustness: bool = False):
    task = make_task(get_app(name), n_train=n_train, n_test=n_test)
    return explore(task, family=family, n_sites=n_sites, seed=seed,
                   robustness=robustness, **budget(full))
