"""Assemble experiments/dryrun/*.json into the §Dry-run/§Roofline tables
(markdown written to experiments/roofline.md, rows returned for run.py)."""
from __future__ import annotations

import glob
import json
import os
from typing import List, Tuple

Row = Tuple[str, float, str]

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")
OUT_MD = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "roofline.md")


def load_records(d: str = DRYRUN_DIR) -> List[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def _fmt(x, digits=4):
    return f"{x:.{digits}g}"


def render_markdown(recs: List[dict]) -> str:
    lines = ["# Roofline table (single-pod 16x16 = 256 chips, TPU v5e "
             "constants)", "",
             "| arch | shape | status | compute_s | memory_s (census) | "
             "analytic_mem_s | collective_s | bottleneck | MFU | "
             "useful-FLOP ratio | temp GiB/chip |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if "single_pod" not in r.get("mesh", ""):
            continue
        if r["status"] != "ok":
            reason = r.get("reason", r.get("error", ""))[:60]
            lines.append(f"| {r['arch']} | {r['shape']} | {r['status']}: "
                         f"{reason} | | | | | | | | |")
            continue
        ro = r["roofline"]
        temp = r["memory_analysis"].get("temp_size_in_bytes", 0) / 2 ** 30
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | {_fmt(ro['compute_s'])} "
            f"| {_fmt(ro['memory_s'])} | {_fmt(ro['analytic_memory_s'])} "
            f"| {_fmt(ro['collective_s'])} | {ro['bottleneck']} "
            f"| {_fmt(ro['mfu'], 3)} | {_fmt(ro['useful_flop_ratio'], 3)} "
            f"| {temp:.2f} |")
    lines += ["", "# Multi-pod (2x16x16 = 512 chips) dry-run status", "",
              "(lower+compile pass/fail — proves the 'pod' axis shards; "
              "the roofline table above is single-pod per the assignment)",
              "",
              "| arch | shape | status |", "|---|---|---|"]
    for r in recs:
        if "multi_pod" not in r.get("mesh", ""):
            continue
        note = "" if r["status"] != "skipped" else " (documented skip)"
        lines.append(f"| {r['arch']} | {r['shape']} "
                     f"| {r['status']}{note} |")
    return "\n".join(lines) + "\n"


def roofline_rows(full: bool = False) -> List[Row]:
    recs = load_records()
    if not recs:
        return [("roofline/table", 0.0, "no dryrun records yet")]
    md = render_markdown(recs)
    os.makedirs(os.path.dirname(OUT_MD), exist_ok=True)
    with open(OUT_MD, "w") as f:
        f.write(md)
    ok = sum(1 for r in recs if r["status"] == "ok")
    skip = sum(1 for r in recs if r["status"] == "skipped")
    fail = sum(1 for r in recs if r["status"] == "error")
    rows: List[Row] = [("roofline/summary", 0.0,
                        f"ok={ok};skipped={skip};failed={fail};"
                        f"md={os.path.relpath(OUT_MD)}")]
    # headline: worst and best MFU among ok single-pod cells
    cells = [(r["arch"] + "/" + r["shape"], r["roofline"]["mfu"])
             for r in recs if r["status"] == "ok"
             and "single_pod" in r["mesh"]]
    if cells:
        worst = min(cells, key=lambda kv: kv[1])
        best = max(cells, key=lambda kv: kv[1])
        rows.append(("roofline/mfu_range", 0.0,
                     f"worst={worst[0]}:{worst[1]:.3f};"
                     f"best={best[0]}:{best[1]:.3f}"))
    return rows
