"""Multi-page KV blocking + fused bit-census microbench
(``--only kernels-paged``).

The PR-8 kernel rebuild streams ``pages_per_block`` block-table entries
per KV grid step, so ``block_k = pages_per_block * page_size`` fills the
(8, 128) MXU tile even at ``page_size in {8, 16, 32}``, and fuses the
NEAT trailing-zero bit census into the kernel epilogues so serving
emits exact per-phase dynamic censuses at zero extra dispatches.

Deterministic forms gated by ``check_smoke``:

* **blocking** — the KV grid trip count at ``page_size=8 x ppb=16``
  must equal the ``page_size=128 x ppb=1`` reference (small pages stop
  costing grid steps), and a paged serve at ``page_size=8`` with
  ``pages_per_block=8`` must take no more compiled engine steps than
  the wide-page layout, with byte-identical greedy completions;
* **census parity** — the kernel-epilogue census (SMEM accumulator,
  interpret backend) must match the host ``bit_census_ref`` of the
  returned output within ``DYNAMIC_HOST_DEVICE_RTOL`` for flash /
  paged-flash / quant-matmul at full and truncated mantissas;
* **zero-dispatch serving census** — a paged serve with
  ``estimate_energy=True`` may issue at most
  ``MAX_DYNAMIC_EXTRA_DISPATCHES`` more compiled steps than the same
  run with it off, while folding a nonzero measured census and keeping
  completions identical.

Rows follow the harness convention: (name, us_per_call, derived).
"""
from __future__ import annotations

import time
from typing import List, Tuple


def _pool_from_contiguous(k, v, page_size: int, num_pages: int):
    """Scatter contiguous (B, Hkv, S, D) K/V into a paged pool plus
    per-row block tables (row b's pages interleaved across the pool)."""
    import jax.numpy as jnp
    import numpy as np

    b, hkv, s, d = k.shape
    mp = s // page_size
    kp = np.zeros((num_pages, page_size, hkv, d), np.float32)
    vp = np.zeros_like(kp)
    tbl = np.zeros((b, mp), np.int32)
    for bi in range(b):
        for pi in range(mp):
            page = bi * mp + pi
            tbl[bi, pi] = page
            sl = slice(pi * page_size, (pi + 1) * page_size)
            kp[page] = np.asarray(k[bi, :, sl]).transpose(1, 0, 2)
            vp[page] = np.asarray(v[bi, :, sl]).transpose(1, 0, 2)
    return jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(tbl)


def _kernel_cells(full: bool) -> List[Tuple[str, float, str]]:
    """Interpret-backend paged kernel across (page_size, ppb) cells:
    wall clock, KV grid trips, oracle error."""
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    b, hq, hkv, d, tq, s = 2, 2, 1, 16, 8, 128
    q = jnp.asarray(rng.standard_normal((b, hq, tq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    kv_len = jnp.asarray([s, s // 2 + 1], jnp.int32)
    q_start = kv_len - tq
    want = np.asarray(ref.flash_attention_ref(
        q, k, v, causal=True, kv_len=kv_len, q_start=q_start))

    cells = [(128, 1), (8, 1), (8, 16), (16, 8), (32, 4)]
    if full:
        cells += [(8, 4), (16, 1), (64, 2)]
    rows, trips = [], {}
    for ps, ppb in cells:
        mp = s // ps
        kp, vp, tbl = _pool_from_contiguous(k, v, ps, b * mp)
        kv_steps = -(-mp // ppb)          # padded table blocks per row
        trips[(ps, ppb)] = kv_steps
        got = ops.paged_flash_attention(   # compile/trace warmup
            q, kp, vp, tbl, causal=True, kv_len=kv_len, q_start=q_start,
            pages_per_block=ppb, backend="interpret")
        err = float(np.max(np.abs(np.asarray(got) - want)))
        reps = 3 if full else 2
        t0 = time.perf_counter()
        for _ in range(reps):
            ops.paged_flash_attention(
                q, kp, vp, tbl, causal=True, kv_len=kv_len,
                q_start=q_start, pages_per_block=ppb,
                backend="interpret").block_until_ready()
        us = (time.perf_counter() - t0) / reps * 1e6
        rows.append((f"kernels_paged_ps{ps}_ppb{ppb}", us,
                     f"block_k={ps * ppb};kv_steps={kv_steps};"
                     f"max_err={err:.2e}"))
    small, wide = trips[(8, 16)], trips[(128, 1)]
    rows.append(("kernels_paged_blocking", 0.0,
                 f"small_page_kv_steps={small};"
                 f"full_tile_kv_steps={wide};"
                 f"tile_filled={small <= wide}"))
    return rows


def _census_parity() -> Tuple[str, float, str]:
    """Kernel-epilogue census vs host ``bit_census_ref`` of the kernel's
    own output, across the three censused kernels x mantissa widths."""
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops, ref

    rng = np.random.default_rng(1)
    rel, cases = 0.0, 0

    def check(out, census):
        nonlocal rel, cases
        host = int(ref.bit_census_ref(out))
        rel = max(rel, abs(int(census) - host) / max(host, 1))
        cases += 1

    b, hq, hkv, d, s = 2, 2, 1, 16, 64
    q = jnp.asarray(rng.standard_normal((b, hq, 8, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    kv_len = jnp.asarray([s, s // 2 + 1], jnp.int32)
    for bits in (24, 8):
        check(*ops.flash_attention(q, k, v, causal=True, kv_len=kv_len,
                                   q_start=kv_len - 8, pv_bits=bits,
                                   collect_census=True,
                                   backend="interpret"))
    kp, vp, tbl = _pool_from_contiguous(k, v, 8, 2 * (s // 8))
    for ppb in (1, 2):
        check(*ops.paged_flash_attention(
            q, kp, vp, tbl, causal=True, kv_len=kv_len, q_start=kv_len - 8,
            pages_per_block=ppb, collect_census=True, backend="interpret"))
    a = jnp.asarray(rng.standard_normal((100, 70)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((70, 90)), jnp.float32)
    for bits in (24, 10):
        check(*ops.quant_matmul(a, w, a_bits=bits, b_bits=bits,
                                collect_census=True, backend="interpret"))
    return ("kernels_paged_census", 0.0,
            f"max_rel_diff={rel:.1e};cases={cases}")


def kernels_paged(full: bool = False) -> List[Tuple[str, float, str]]:
    import jax

    from repro.configs import get_arch
    from repro.models import build_model
    from repro.serve import DecodeEngine, ServeConfig
    from repro.serve.engine import KVConfig

    rows = _kernel_cells(full)
    rows.append(_census_parity())

    # serving layer: small pages + multi-page blocks vs wide pages, and
    # the fused census's dispatch cost
    cfg = get_arch("codeqwen1.5-7b").reduced(n_layers=2, d_model=32,
                                             d_ff=64, vocab=64)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    n_req = 16 if full else 8
    max_new = 8
    slots, max_len = 4, 64
    prompts = [[(7 * i + 3 + j) % cfg.vocab_size
                for j in range(24 if i % 4 == 0 else 4)]
               for i in range(n_req)]

    def serve(page_size, ppb, energy=False):
        eng = DecodeEngine(model, params, ServeConfig(
            max_len=max_len, batch_slots=slots, engine="continuous",
            prefill_chunk=8,
            kv=KVConfig(page_size=page_size,
                        pages=slots * max_len // page_size,
                        pages_per_block=ppb),
            estimate_energy=energy))
        eng.generate(prompts, max_new_tokens=max_new)   # compile warmup
        t0 = time.perf_counter()
        outs = eng.generate(prompts, max_new_tokens=max_new)
        us = (time.perf_counter() - t0) * 1e6
        return dict(outs=outs, us=us, steps=eng.stats.steps,
                    stats=eng.stats)

    small = serve(8, 8)
    wide = serve(64, 1)
    census = serve(8, 8, energy=True)
    st = census["stats"]
    extra = census["steps"] - small["steps"]
    parity = (small["outs"] == wide["outs"]
              and census["outs"] == small["outs"])
    nonzero = st.measured_pj > 0 and bool(st.phase_census)

    rows += [
        ("kernels_paged_serve_small", small["us"],
         f"steps={small['steps']};page_size=8;pages_per_block=8"),
        ("kernels_paged_serve_wide", wide["us"],
         f"steps={wide['steps']};page_size=64;pages_per_block=1"),
        ("kernels_paged_serve_census", census["us"],
         f"steps_static={small['steps']};steps_census={census['steps']};"
         f"extra_dispatches={extra};"
         f"measured_pj_per_tok={st.measured_pj_per_token:.4e};"
         f"census_nonzero={nonzero};parity={parity}"),
    ]
    return rows


if __name__ == "__main__":
    for name, us, derived in kernels_paged():
        print(f"{name},{us:.0f},{derived}")
