"""Paper §V-H: LeNet-5/MNIST case study — Fig. 10 (FLOP breakdown),
Fig. 11 (PLC vs PLI), Table V (per-layer mantissa bits)."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import budget
from repro.core import ExplorationTask, explore, profile
from repro.data.synthetic import synthetic_digits
from repro.models.lenet import (accuracy, init_lenet5, lenet5_forward,
                                lenet5_loss)

Row = Tuple[str, float, str]

LAYER_ORDER = ("conv1", "avgpool1", "conv2", "avgpool2", "conv3", "fc",
               "tanh", "internal")


def _train_lenet(steps: int = 80, n: int = 512):
    imgs, labels = synthetic_digits(n, seed=0)
    params = init_lenet5(jax.random.key(0))

    @jax.jit
    def step(p):
        g = jax.grad(lenet5_loss)(p, imgs, labels)
        return jax.tree.map(lambda a, b: a - 0.05 * b, p, g)

    for _ in range(steps):
        params = step(params)
    return params, imgs, labels


def _acc_error(params, labels):
    """Error metric = accuracy drop vs the exact model (paper's 'accuracy
    loss')."""
    lab = np.asarray(labels)

    def err_fn(approx_logits, exact_logits):
        a = np.argmax(np.asarray(approx_logits), -1).reshape(-1)
        e = np.argmax(np.asarray(exact_logits), -1).reshape(-1)
        n = len(a)
        return max(0.0, float(np.mean(e == lab[:n]) - np.mean(a == lab[:n])))
    return err_fn


def lenet_case_study(full: bool = False) -> List[Row]:
    rows = []
    t0 = time.perf_counter()
    params, imgs, labels = _train_lenet(steps=60 if not full else 120)
    base_acc = float(accuracy(params, imgs, labels))
    eval_imgs = imgs[:256]
    eval_labels = labels[:256]

    # Fig. 10: FLOP breakdown per layer
    prof = profile(lenet5_forward, params, eval_imgs)
    by_leaf = {}
    for path, st in prof.scopes.items():
        leaf = path.split("/")[-1] if path else ""
        by_leaf[leaf] = by_leaf.get(leaf, 0) + st.flops
    tot = max(prof.total_flops, 1)
    conv_share = sum(v for k, v in by_leaf.items()
                     if k.startswith("conv")) / tot
    rows.append(("fig10/lenet_flops", (time.perf_counter() - t0) * 1e6,
                 f"base_acc={base_acc:.3f};conv_share={conv_share:.2f}"))

    # Fig. 11 + Table V: PLC vs PLI exploration over layer scopes
    fwd = lambda im: lenet5_forward(params, im)
    task = ExplorationTask(
        name="lenet", fn=fwd,
        train_inputs=[(eval_imgs,)],
        test_inputs=[(imgs[256:448],)],
        error_fn=_acc_error(params, eval_labels))
    reports = {}
    for family in ("plc", "pli"):
        t1 = time.perf_counter()
        rep = explore(task, family=family, n_sites=8, robustness=False,
                      **budget(full))
        us = (time.perf_counter() - t1) * 1e6
        reports[family] = rep
        parts = [f"sav@{int(t*100)}%={rep.savings(t):.3f}"
                 for t in (0.01, 0.05, 0.10)]
        rows.append((f"fig11/lenet_{family}", us,
                     ";".join(parts) + f";sites={len(rep.sites)}"))

    # Table V: recommended per-layer bits at each error budget (PLI)
    rep = reports["pli"]
    for thr in (0.01, 0.05, 0.10):
        genome = rep.best_genome(thr)
        if genome is None:
            continue
        named = {}
        for site, bits in zip(rep.sites, genome):
            leaf = site.split("/")[-1]
            named[leaf] = min(named.get(leaf, 24), int(bits))
        cells = ";".join(f"{k}={named.get(k, 24)}" for k in LAYER_ORDER
                         if k in named or k in ("tanh", "internal"))
        rows.append((f"table5/bits@{int(thr*100)}%", 0.0, cells))
    return rows
