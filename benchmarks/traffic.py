"""Bench-side traffic surface: re-exports the seeded open-loop
generator from :mod:`repro.serve.traffic` (the implementation lives in
``src`` so the launcher can import it too) and adds the canned burst
workloads the ``serve-burst`` bench and its CI gates run against."""
from __future__ import annotations

from typing import List

from repro.serve.traffic import (TrafficConfig, TrafficRequest,  # noqa: F401
                                 generate_traffic)


def burst_workload(n_requests: int, seed: int = 0,
                   rate_rps: float = 200.0) -> List[TrafficRequest]:
    """The serve-burst open-loop workload: Poisson arrivals fast enough
    that the queue builds real depth on a tiny CPU model, long-tail
    prompt lengths, two priority classes. Deadlines are NOT drawn here —
    the bench injects deterministic poison requests instead, so the
    gated shed counts never depend on wall clock."""
    return generate_traffic(TrafficConfig(
        n_requests=n_requests, seed=seed, process="poisson",
        rate_rps=rate_rps, prompt_mean=5.0, prompt_sigma=0.5,
        prompt_max=12, decode_mean=20.0, decode_sigma=0.3,
        decode_max=24, vocab=64, priority_weights=(3.0, 1.0)))
