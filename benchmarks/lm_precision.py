"""Beyond-paper: NEAT applied to an LM — per-layer-class mantissa
precision for a (reduced) assigned architecture, the LLM-scale analogue of
the paper's CNN study. Uses scope-mode placement on the real model code
(the same scopes the production stack runs under)."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import budget
from repro.configs import get_arch
from repro.core import ExplorationTask, explore
from repro.models import build_model

Row = Tuple[str, float, str]


def lm_precision(full: bool = False, arch: str = "h2o-danube-3-4b"
                 ) -> List[Row]:
    cfg = get_arch(arch).reduced(n_layers=2, d_model=64, n_heads=4,
                                 d_ff=128, vocab=256)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (4, 32), 0,
                              cfg.vocab_size)

    fwd = lambda t: model.forward(params, t)
    task = ExplorationTask(
        name=f"lm/{arch}", fn=fwd,
        train_inputs=[(toks,)],
        test_inputs=[(jax.random.randint(jax.random.key(2), (4, 32), 0,
                                         cfg.vocab_size),)])
    t0 = time.perf_counter()
    rep = explore(task, family="plc", n_sites=8, robustness=False,
                  **budget(full))
    us = (time.perf_counter() - t0) * 1e6
    parts = [f"sav@{int(t*100)}%={rep.savings(t):.3f}"
             for t in (0.01, 0.05, 0.10)]
    g = rep.best_genome(0.05)
    if g is not None:
        parts.append("bits@5%=" + ",".join(
            f"{s.split('/')[-1]}:{b}" for s, b in zip(rep.sites, g)))
    return [(f"lm_precision/{arch}", us, ";".join(parts))]
