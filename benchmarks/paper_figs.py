"""One function per paper table/figure. Each returns
(name, us_per_call, derived) rows for run.py's CSV."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import numpy as np

from benchmarks.common import APPS_F32, budget, explore_app, timed
from repro.apps import get_app, make_task
from repro.core import (CallStack, CurrentScope, MantissaTrunc, explore,
                        harmonic_mean, neat_transform, profile)

Row = Tuple[str, float, str]


def fig04_flop_breakdown(full: bool = False) -> List[Row]:
    """Fig. 4: single/double FLOP ratio per benchmark."""
    rows = []
    apps = list(APPS_F32) + ["ferret", "particlefilter"]
    for name in apps:
        ctx = jax.experimental.enable_x64() if name in (
            "ferret", "particlefilter") else _null()
        with ctx:
            task = make_task(get_app(name), n_train=1, n_test=0)
            us, prof = timed(profile, get_app(name).fn,
                             *task.train_inputs[0])
            d = prof.dtype_breakdown()
            tot = max(sum(d.values()), 1)
            f32 = d.get("float32", 0) / tot
            f64 = d.get("float64", 0) / tot
        rows.append((f"fig04/{name}", us,
                     f"f32={f32:.2f};f64={f64:.2f};flops={prof.total_flops}"))
    return rows


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


def fig05_06_wp_vs_cip(full: bool = False) -> List[Row]:
    """Fig. 5 (hulls) + Fig. 6 (quantized savings): WP vs CIP per app."""
    rows = []
    sav_cip, sav_wp = {0.01: [], 0.05: [], 0.10: []}, \
        {0.01: [], 0.05: [], 0.10: []}
    for name in APPS_F32:
        t0 = time.perf_counter()
        rep_wp = explore_app(name, "wp", full=full, n_sites=1)
        rep_cip = explore_app(name, "cip", full=full)
        us = (time.perf_counter() - t0) * 1e6
        parts = []
        for thr in (0.01, 0.05, 0.10):
            sw, sc = rep_wp.savings(thr), rep_cip.savings(thr)
            sav_wp[thr].append(max(sw, 1e-6))
            sav_cip[thr].append(max(sc, 1e-6))
            parts.append(f"wp@{int(thr*100)}%={sw:.3f};"
                         f"cip@{int(thr*100)}%={sc:.3f}")
        hull = ";".join(f"({p.error:.4f},{p.energy:.3f})"
                        for p in rep_cip.hull[:6])
        rows.append((f"fig05/{name}", us, ";".join(parts) + ";hull=" + hull))
    for thr in (0.01, 0.05, 0.10):
        extra = harmonic_mean(sav_cip[thr]) - harmonic_mean(sav_wp[thr])
        rows.append((f"fig06/hmean@{int(thr*100)}%", 0.0,
                     f"cip_minus_wp={extra:+.3f};"
                     f"cip={harmonic_mean(sav_cip[thr]):.3f};"
                     f"wp={harmonic_mean(sav_wp[thr]):.3f}"))
    return rows


def fig07_memory_savings(full: bool = False) -> List[Row]:
    """Fig. 7: memory-transfer energy savings at error thresholds (CIP)."""
    rows = []
    for name in APPS_F32:
        t0 = time.perf_counter()
        rep = explore_app(name, "cip", full=full)
        us = (time.perf_counter() - t0) * 1e6
        parts = [f"mem@{int(t*100)}%={rep.mem_savings(t):.3f}"
                 for t in (0.01, 0.05, 0.10)]
        rows.append((f"fig07/{name}", us, ";".join(parts)))
    return rows


def fig08_precision_target(full: bool = False) -> List[Row]:
    """Fig. 8: optimization-target study on the mixed-precision app."""
    rows = []
    with jax.experimental.enable_x64():
        for target in ("single", "double"):
            task = make_task(get_app("ferret"), n_train=2, n_test=1)
            task.target = target
            t0 = time.perf_counter()
            rep = explore(task, family="cip", n_sites=4,
                          robustness=False, **budget(full))
            us = (time.perf_counter() - t0) * 1e6
            parts = [f"sav@{int(t*100)}%={rep.savings(t):.3f}"
                     for t in (0.01, 0.05, 0.10)]
            rows.append((f"fig08/ferret_{target}", us, ";".join(parts)))
    return rows


def fig09_fcs_radar(full: bool = False) -> List[Row]:
    """Fig. 9: CIP vs FCS on radar (caller-sensitive FFT precision)."""
    t0 = time.perf_counter()
    rep_cip = explore_app("radar", "cip", full=full, seed=3)
    rep_fcs = explore_app("radar", "fcs", full=full, seed=3)
    us = (time.perf_counter() - t0) * 1e6
    parts = []
    for thr in (0.01, 0.05, 0.10):
        parts.append(f"cip@{int(thr*100)}%={rep_cip.savings(thr):.3f};"
                     f"fcs@{int(thr*100)}%={rep_fcs.savings(thr):.3f}")
    return [("fig09/radar_cip_vs_fcs", us, ";".join(parts))]


def table3_robustness(full: bool = False) -> List[Row]:
    """Table III: train->test correlation coefficients."""
    rows = []
    for name in APPS_F32:
        t0 = time.perf_counter()
        rep = explore_app(name, "cip", full=full, robustness=True,
                          n_train=3, n_test=3)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"table3/{name}", us,
                     f"R_error={rep.robustness_error_r:.3f};"
                     f"R_energy={rep.robustness_energy_r:.3f}"))
    return rows
