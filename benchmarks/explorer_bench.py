"""Population-batched explorer vs. the historical serial path.

Measures, on the quickstart app (blackscholes, CIP family):

* steady-state wall-clock to evaluate a 40-genome population's error
  matrix (batched = one compiled vmapped call; serial = one compiled
  call per genome per train input),
* compiled-dispatch counts for a full NSGA-II exploration, and
* that both paths produce the identical Pareto front for the same seed.

Rows follow the harness convention: (name, us_per_call, derived).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import numpy as np


def explorer_population(full: bool = False) -> List[Tuple[str, float, str]]:
    from repro.apps import get_app, make_task
    from repro.core import explore
    from repro.core.explorer import PopulationEvaluator, sites_for_family
    from repro.core.profiler import profile

    pop_size = 40
    n_gen = 9 if full else 3
    max_evals = 400 if full else 80

    task = make_task(get_app("blackscholes"), n_train=3, n_test=2)
    prof = profile(task.fn, *task.train_inputs[0])
    sites = sites_for_family(prof, "cip", 4)
    exact = [jax.tree.map(np.asarray, task.fn(*inp))
             for inp in task.train_inputs]

    ev = PopulationEvaluator(task, "cip", sites, pop_hint=pop_size)
    rng = np.random.default_rng(0)
    genomes = [tuple(int(v) for v in rng.integers(1, 25, len(sites)))
               for _ in range(pop_size)]

    # warm both compiled paths, then time steady state
    ev.errors_matrix(genomes, task.train_inputs, exact)
    ev.errors_serial(genomes[0], task.train_inputs, exact)
    reps = 5
    t0 = time.perf_counter()
    for _ in range(reps):
        mat_b = ev.errors_matrix(genomes, task.train_inputs, exact)
    us_batched = (time.perf_counter() - t0) / reps * 1e6
    t0 = time.perf_counter()
    for _ in range(reps):
        mat_s = np.asarray([ev.errors_serial(g, task.train_inputs, exact)
                            for g in genomes])
    us_serial = (time.perf_counter() - t0) / reps * 1e6
    parity = bool(np.allclose(mat_b, mat_s, rtol=1e-6, atol=1e-9))

    # full explorations: dispatch counts + front identity
    rep_b = explore(task, family="cip", n_sites=4, pop_size=pop_size,
                    n_gen=n_gen, max_evals=max_evals, seed=0, batched=True,
                    robustness=False)
    rep_s = explore(task, family="cip", n_sites=4, pop_size=pop_size,
                    n_gen=n_gen, max_evals=max_evals, seed=0, batched=False,
                    robustness=False)
    front_b = [p.payload["genome"] for p in rep_b.hull]
    front_s = [p.payload["genome"] for p in rep_s.hull]

    return [
        ("explorer_pop40_batched", us_batched,
         f"speedup={us_serial / max(us_batched, 1e-9):.2f}x"),
        ("explorer_pop40_serial", us_serial, f"parity={parity}"),
        ("explorer_dispatches", 0.0,
         f"batched={rep_b.n_dispatches};serial={rep_s.n_dispatches}"),
        ("explorer_front_identical", 0.0,
         f"{front_b == front_s};n_evals={rep_b.n_evals}"),
    ]


def explorer_dynamic(full: bool = False) -> List[Tuple[str, float, str]]:
    """Dynamic (trailing-zero) energy objective vs the static path.

    Gated properties (benchmarks.check_smoke):

    * a dynamic-objective exploration issues at most 2 more compiled
      dispatches than the static objective at identical budget — the
      bit-census accumulators ride the existing vmapped dispatch;
    * per-(genome, input) device-folded dynamic FPU energy matches the
      host-side ``capture_bit_census`` + ``dynamic_fpu_energy`` reference
      to 1e-6 relative;
    * dynamic energy never exceeds static for identical genomes.
    """
    from repro.apps import get_app, make_task
    from repro.core import explore
    from repro.core.estimators import host_device_parity, make_estimator
    from repro.core.explorer import PopulationEvaluator, sites_for_family
    from repro.core.profiler import profile

    pop_size = 40
    n_gen = 9 if full else 3
    max_evals = 400 if full else 80

    task = make_task(get_app("blackscholes"), n_train=3, n_test=2)
    prof = profile(task.fn, *task.train_inputs[0])
    sites = sites_for_family(prof, "cip", 4)
    exact = [jax.tree.map(np.asarray, task.fn(*inp))
             for inp in task.train_inputs]

    # host/device dynamic-energy agreement on a probe batch (the same
    # shared contract tests/test_energy_dynamic.py asserts)
    ev = PopulationEvaluator(task, "cip", sites, pop_hint=8,
                             collect_bits=True)
    rng = np.random.default_rng(0)
    genomes = [tuple(int(v) for v in rng.integers(1, 25, len(sites)))
               for _ in range(8)]
    ev.errors_matrix(genomes, task.train_inputs, exact)
    est = make_estimator("dynamic", prof, "cip", sites, target=task.target)
    worst = host_device_parity(task, "cip", sites, est, ev, genomes,
                               task.train_inputs)

    stat = make_estimator("static", prof, "cip", sites, target=task.target)
    sf, _ = stat.population(genomes)
    df, _ = est.population(genomes, evaluator=ev)
    dyn_le_static = bool(np.all(df <= sf * (1 + 1e-9)))

    # full explorations at equal budget: the dispatch-count delta
    t0 = time.perf_counter()
    rep_d = explore(task, family="cip", n_sites=4, pop_size=pop_size,
                    n_gen=n_gen, max_evals=max_evals, seed=0,
                    energy="dynamic", robustness=False)
    us_dyn = (time.perf_counter() - t0) * 1e6
    rep_s = explore(task, family="cip", n_sites=4, pop_size=pop_size,
                    n_gen=n_gen, max_evals=max_evals, seed=0,
                    energy="static", robustness=False)

    return [
        ("explorer_dynamic_run", us_dyn,
         f"n_evals={rep_d.n_evals};estimator={rep_d.energy_estimator}"),
        ("explorer_dynamic_dispatches", 0.0,
         f"dynamic={rep_d.n_dispatches};static={rep_s.n_dispatches}"),
        ("explorer_dynamic_host_device", 0.0,
         f"max_rel_diff={worst:.3e}"),
        ("explorer_dynamic_sanity", 0.0,
         f"dyn_le_static={dyn_le_static}"),
    ]


if __name__ == "__main__":
    for name, us, derived in explorer_population() + explorer_dynamic():
        print(f"{name},{us:.0f},{derived}")
