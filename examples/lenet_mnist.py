"""Paper §V-H: per-layer precision tuning of a LeNet-5 digit classifier —
trains the CNN, then finds the minimum mantissa bits per layer instance
(PLI) under accuracy-loss budgets (Table V analogue).

  PYTHONPATH=src python examples/lenet_mnist.py
"""
import jax
import numpy as np

from repro.core import ExplorationTask, explore
from repro.data.synthetic import synthetic_digits
from repro.models.lenet import (accuracy, init_lenet5, lenet5_forward,
                                lenet5_loss)

# train
imgs, labels = synthetic_digits(512, seed=0)
params = init_lenet5(jax.random.key(0))


@jax.jit
def step(p):
    g = jax.grad(lenet5_loss)(p, imgs, labels)
    return jax.tree.map(lambda a, b: a - 0.05 * b, p, g)


for i in range(80):
    params = step(params)
print(f"baseline accuracy: {float(accuracy(params, imgs, labels)):.3f}")


# accuracy-loss error metric (the paper's metric for the CNN study)
def err_fn(approx, exact):
    a = np.argmax(np.asarray(approx), -1).reshape(-1)
    e = np.argmax(np.asarray(exact), -1).reshape(-1)
    lab = np.asarray(labels)[: len(a)]
    return max(0.0, float(np.mean(e == lab) - np.mean(a == lab)))


task = ExplorationTask(
    name="lenet5", fn=lambda im: lenet5_forward(params, im),
    train_inputs=[(imgs[:256],)], test_inputs=[(imgs[256:],)],
    error_fn=err_fn)

report = explore(task, family="pli", n_sites=8, pop_size=16, n_gen=5,
                 max_evals=120, seed=0, robustness=False)

print(f"\nexplored {report.n_evals} per-layer configurations")
for thr in (0.01, 0.05, 0.10):
    g = report.best_genome(thr)
    if g is None:
        continue
    print(f"@ {int(thr*100)}% accuracy loss -> mantissa bits per layer:")
    for site, bits in zip(report.sites, g):
        print(f"    {site:28s} {bits:2d} / 24")
