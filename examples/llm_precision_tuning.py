"""Beyond-paper: NEAT per-layer-class precision for an assigned LM arch
(reduced config). The same placement machinery the CNN study used, on the
production model code — the bits NEAT picks feed the scope-mode STE
truncation for serving (launch/serve.py --rule).

  PYTHONPATH=src python examples/llm_precision_tuning.py
"""
import jax

from repro.configs import get_arch
from repro.core import ExplorationTask, explore
from repro.models import build_model

cfg = get_arch("h2o-danube-3-4b").reduced(n_layers=2, d_model=64,
                                          n_heads=4, d_ff=128, vocab=256)
model = build_model(cfg)
params = model.init(jax.random.key(0))
toks = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size)
toks2 = jax.random.randint(jax.random.key(2), (4, 32), 0, cfg.vocab_size)

task = ExplorationTask(
    name=f"lm/{cfg.name}", fn=lambda t: model.forward(params, t),
    train_inputs=[(toks,)], test_inputs=[(toks2,)])

report = explore(task, family="plc", n_sites=8, pop_size=14, n_gen=4,
                 max_evals=80, seed=0)

print(f"explored {report.n_evals} configs over layer classes:")
print("  sites:", report.sites)
for thr in (0.01, 0.05, 0.10):
    print(f"savings @ {int(thr*100)}% output error: "
          f"{report.savings(thr)*100:.1f}%")
g = report.best_genome(0.05)
if g is not None:
    print("recommended bits @5%:",
          {s.split('/')[-1]: int(b) for s, b in zip(report.sites, g)})
print(f"robustness R_error = {report.robustness_error_r:.3f}")
