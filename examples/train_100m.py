"""End-to-end driver: train a ~100M-param LM for a few hundred steps on
the local devices, with NEAT reduced-precision QAT (STE mantissa
truncation under a placement rule), checkpoint/restart, then serve a few
completions from the trained weights.

  PYTHONPATH=src python examples/train_100m.py [--steps 200]
"""
import argparse
import dataclasses
import tempfile

import jax

from repro.configs import get_arch
from repro.core import MantissaTrunc, WholeProgram
from repro.data.synthetic import SyntheticLMDataset
from repro.models import build_model
from repro.serve import DecodeEngine, ServeConfig
from repro.train import Trainer, TrainerConfig
from repro.utils.tree import tree_count_params

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--bits", type=int, default=10,
                help="NEAT WP mantissa bits for QAT")
args = ap.parse_args()

# ~100M params: granite family reduced to 12L x 512
cfg = get_arch("granite-moe-1b-a400m").reduced(
    n_layers=12, d_model=512, n_heads=8, d_ff=256, vocab=8192)
cfg = dataclasses.replace(cfg, moe_impl="ragged")
model = build_model(cfg)
params = model.init(jax.random.key(0))
print(f"arch={cfg.name} (reduced) params="
      f"{tree_count_params(params)/1e6:.1f}M")

rule = WholeProgram(fpi=MantissaTrunc(args.bits), target="single")
ds = SyntheticLMDataset(cfg.vocab_size, seq_len=128, global_batch=8)

with tempfile.TemporaryDirectory() as ckdir:
    tcfg = TrainerConfig(peak_lr=1e-3, warmup_steps=20,
                         total_steps=args.steps, microbatches=2,
                         checkpoint_dir=ckdir, checkpoint_every=100)
    trainer = Trainer(model.loss, tcfg, rule=rule)
    params, _, hist = trainer.fit(params, lambda s: ds.batch(s),
                                  steps=args.steps, log_every=25)
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"(QAT @ {args.bits} mantissa bits)")

engine = DecodeEngine(model, params, ServeConfig(max_len=160,
                                                 batch_slots=4),
                      rule=rule)
outs = engine.generate([[1, 2, 3], [10, 11], [42], [7, 8, 9]],
                       max_new_tokens=12)
for i, o in enumerate(outs):
    print(f"completion {i}: {o}")
