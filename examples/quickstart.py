"""Quickstart: explore the accuracy/energy tradeoff of a program with
NEAT — the paper's §IV workflow in ~40 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.apps import get_app, make_task
from repro.core import explore, profile

# 1. Profile the program (paper step 1): which functions burn FLOPs?
app = get_app("blackscholes")
task = make_task(app, n_train=3, n_test=2)
prof = profile(app.fn, *task.train_inputs[0])
print("top FLOP functions:", prof.top_functions(5))
print("coverage of top-5:", round(prof.coverage(prof.top_functions(5)), 3))

# 2-5. Pick a placement family, let NSGA-II explore (<=400 configs),
#      and read the frontier (paper steps 2-5).
report = explore(task, family="cip", n_sites=4,
                 pop_size=16, n_gen=5, max_evals=120, seed=0)

print(f"\nexplored {report.n_evals} configurations")
print("lower convex hull (error rate, normalized FPU energy):")
for p in report.hull:
    print(f"  err={p.error:8.5f}  energy={p.energy:6.3f}  "
          f"bits={p.payload['genome']}")

for thr in (0.01, 0.05, 0.10):
    print(f"FPU energy savings @ {int(thr*100)}% error budget: "
          f"{report.savings(thr)*100:.1f}%")
print(f"robustness on unseen inputs: R_error="
      f"{report.robustness_error_r:.3f}")
